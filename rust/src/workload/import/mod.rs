//! Trace zoo: import adapters for public production traces.
//!
//! The scenario suite's synthetic shapes and the repo's own JSONL replay
//! format cover "traffic you can imagine" and "traffic this repo
//! recorded". Real evaluations (DistServe arXiv:2401.09670, BurstGPT
//! arXiv:2401.17644, Azure's LLM inference dataset from Splitwise
//! arXiv:2311.18677) replay *public production* traces; this module
//! converts those external formats into the same canonical workload
//! model every scenario uses, with two consumption paths:
//!
//! - **Materialized** ([`import_trace`]): parse the whole file into a
//!   [`ReplayTrace`], exactly like the native JSONL path. Fine up to a
//!   few million records.
//! - **Streaming** ([`StreamedTrace`]): pre-scan the file once for
//!   metadata (span, request count, class mix), then replay it through
//!   [`StreamedTrace::arrivals_at`] — a bounded-memory iterator the
//!   cursor engine consumes directly
//!   ([`crate::sim::run_source_faulted`]), so a multi-day multi-million
//!   request log never lives in memory at once. Peak buffering is the
//!   reorder window ([`StreamedArrivals::peak_buffered`]), not the log
//!   length.
//!
//! Both paths share one line scanner, so they accept and reject exactly
//! the same inputs and emit records in exactly the same order — the
//! streaming replay is locked bit-identical to the materialized one.
//! Files named `*.gz` are gzip-decompressed transparently on both paths
//! (public traces ship compressed; see [`crate::util::gzip`]) —
//! decompression materializes the text, so for logs whose *decompressed*
//! form exceeds memory, gunzip to disk first and stream the plain file.
//!
//! ## Formats and class/SLO mapping
//!
//! | format     | shape                                                        | classes → SLO dataset |
//! |------------|--------------------------------------------------------------|-----------------------|
//! | `burstgpt` | CSV `Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type` | `Conversation log` → "conversation" (ShareGPT SLOs), `API log` → "api" (Alpaca SLOs) |
//! | `azure`    | CSV `TIMESTAMP,ContextTokens,GeneratedTokens`                | single "azure-llm" class (ShareGPT SLOs) |
//!
//! Timestamps are absolute (seconds, or a datetime for Azure); the
//! importer rebases them to trace-relative seconds. Classes the file
//! never uses are dropped from the table (an all-API BurstGPT slice
//! reports one class, not a phantom zero-arrival one).
//!
//! ## Ordering: the bounded reorder window
//!
//! Production exports are *almost* sorted — coarse timestamps and
//! multi-frontend capture reorder neighbors. Both paths tolerate
//! records up to `window` seconds behind the newest timestamp seen
//! (re-sorted by `(timestamp, line order)`, the same tie-break the
//! synthetic merge uses) and reject anything older with the offending
//! line number: silently re-sorting an arbitrarily-shuffled log would
//! need the whole file in memory, which is exactly what streaming
//! exists to avoid.

mod azure;
mod burstgpt;
mod stream;

pub use stream::{StreamedArrivals, StreamedTrace};

use std::io::Cursor;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::datasets::Dataset;
use super::replay::{ReplayClass, ReplayRecord, ReplayTrace};

/// Default reorder tolerance, seconds. Public traces with 1 s timestamp
/// granularity reorder neighbors freely; seconds-apart swaps are capture
/// artifacts, minutes-apart ones are corruption.
pub const DEFAULT_REORDER_WINDOW_S: f64 = 5.0;

/// A supported external trace format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// BurstGPT-style CSV (arXiv:2401.17644 release format).
    BurstGpt,
    /// Azure LLM-inference-style CSV (Splitwise / AzurePublicDataset).
    Azure,
}

impl TraceFormat {
    /// Resolve a `--format` name (case-insensitive).
    pub fn by_name(name: &str) -> Result<TraceFormat> {
        match name.to_ascii_lowercase().as_str() {
            "burstgpt" => Ok(TraceFormat::BurstGpt),
            "azure" => Ok(TraceFormat::Azure),
            other => bail!("unknown trace format '{other}' (expected burstgpt|azure)"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            TraceFormat::BurstGpt => "burstgpt",
            TraceFormat::Azure => "azure",
        }
    }

    /// The full class table this adapter may assign into (before
    /// unused-class compaction). Index order is the `class` field in
    /// [`RawRecord`].
    pub fn classes(self) -> Vec<ReplayClass> {
        match self {
            TraceFormat::BurstGpt => vec![
                ReplayClass { name: "conversation", dataset: Dataset::sharegpt() },
                ReplayClass { name: "api", dataset: Dataset::alpaca() },
            ],
            TraceFormat::Azure => {
                vec![ReplayClass { name: "azure-llm", dataset: Dataset::sharegpt() }]
            }
        }
    }

    /// Validate the file's header row (line 1).
    pub(crate) fn check_header(self, line: &str, src: &str) -> Result<()> {
        match self {
            TraceFormat::BurstGpt => burstgpt::check_header(line, src),
            TraceFormat::Azure => azure::check_header(line, src),
        }
    }

    /// Parse one data row (1-based line number `n` for error messages).
    pub(crate) fn parse_row(self, line: &str, src: &str, n: usize) -> Result<RawRecord> {
        match self {
            TraceFormat::BurstGpt => burstgpt::parse_row(line, src, n),
            TraceFormat::Azure => azure::parse_row(line, src, n),
        }
    }
}

/// One parsed external record in absolute time (the format's native
/// origin; only differences matter — [`assemble`] rebases to the first
/// arrival).
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct RawRecord {
    /// Absolute timestamp, seconds.
    pub t: f64,
    /// Prompt tokens.
    pub input_len: usize,
    /// Generation tokens.
    pub output_len: usize,
    /// Index into [`TraceFormat::classes`] (pre-compaction).
    pub class: usize,
}

/// A CSV token-count field: positive integer (zero-token requests are
/// corrupt — they would divide by zero in TPOT scoring). The `1e12` cap
/// mirrors the JSONL parser's.
pub(crate) fn tokens_field(field: &str, key: &str, src: &str, n: usize) -> Result<usize> {
    let field = field.trim();
    let v: u64 = field.parse().map_err(|_| {
        anyhow::anyhow!("{src}:{n}: '{key}' must be a non-negative integer, got '{field}'")
    })?;
    if v == 0 {
        bail!("{src}:{n}: zero-token request ('{key}' is 0)");
    }
    if v > 1_000_000_000_000 {
        bail!("{src}:{n}: '{key}' {v} is implausibly large");
    }
    Ok(v as usize)
}

/// Provenance string stamped into the imported trace's lineage (and the
/// header `source` field when the trace is re-recorded), so a replay
/// report can always answer "which file, which format, how many
/// requests".
pub(crate) fn lineage_for(format: TraceFormat, src: &str, requests: usize) -> String {
    format!("{} import of '{}' ({} requests)", format.label(), src, requests)
}

/// Drop classes the trace never uses and return `(table, remap)` where
/// `remap[old] = new` for every used index. Keeping phantom classes
/// would report zero-arrival rows and let the scheduler pick an SLO from
/// traffic that does not exist.
pub(crate) fn compact_classes(
    all: Vec<ReplayClass>,
    used: &[bool],
) -> (Vec<ReplayClass>, Vec<usize>) {
    let mut remap = vec![usize::MAX; used.len()];
    let mut out = Vec::new();
    for (k, class) in all.into_iter().enumerate() {
        if used[k] {
            remap[k] = out.len();
            out.push(class);
        }
    }
    (out, remap)
}

/// Finish a materialized import: rebase timestamps to the first arrival,
/// compact the class table, derive the warm-up prefix, and stamp
/// provenance. `raws` must already be in `(timestamp, line)` order (the
/// scanner's emission order), so the constructed trace round-trips
/// bit-for-bit against the streaming path.
fn assemble(raws: Vec<RawRecord>, format: TraceFormat, src: &str) -> Result<ReplayTrace> {
    if raws.is_empty() {
        bail!("{src}: empty trace — no records to replay");
    }
    let t0 = raws[0].t;
    let duration = raws[raws.len() - 1].t - t0;
    if duration <= 0.0 {
        bail!("{src}: trace spans zero seconds — need at least two distinct timestamps");
    }
    let all = format.classes();
    let mut used = vec![false; all.len()];
    for r in &raws {
        used[r.class] = true;
    }
    let (classes, remap) = compact_classes(all, &used);
    let records: Vec<ReplayRecord> = raws
        .iter()
        .map(|r| ReplayRecord {
            arrival: r.t - t0,
            input_len: r.input_len,
            output_len: r.output_len,
            class: remap[r.class],
        })
        .collect();
    let warmup = (duration / 8.0).min(30.0); // the headerless-JSONL rule
    let lineage = lineage_for(format, src, records.len());
    ReplayTrace::from_parts(records, classes, duration, warmup, src.to_string(), Some(lineage))
}

/// Import external trace text under a source label (tests, inline use).
pub fn import_named(
    text: &str,
    format: TraceFormat,
    window: f64,
    src: &str,
) -> Result<ReplayTrace> {
    let mut scan =
        stream::Scanner::new(Cursor::new(text.as_bytes()), format, window, src.to_string());
    let mut raws = Vec::new();
    while let Some(rec) = scan.next_emit()? {
        raws.push(rec);
    }
    assemble(raws, format, src)
}

/// Import an external trace file into a fully-materialized
/// [`ReplayTrace`]. For logs too large to materialize, use
/// [`StreamedTrace::open`] instead — the two paths are bit-identical on
/// any input both can hold. `.gz` files are decompressed transparently
/// (same as the streaming path — one shared scanner, one shared
/// transport).
pub fn import_trace(path: &Path, format: TraceFormat, window: f64) -> Result<ReplayTrace> {
    let text = if stream::is_gz(path) {
        let raw =
            std::fs::read(path).with_context(|| format!("read trace {}", path.display()))?;
        let bytes = crate::util::gzip::gunzip(&raw)
            .map_err(|e| anyhow::anyhow!("decompress {}: {e}", path.display()))?;
        String::from_utf8(bytes).map_err(|_| {
            anyhow::anyhow!("{}: decompressed trace is not valid UTF-8", path.display())
        })?
    } else {
        std::fs::read_to_string(path)
            .with_context(|| format!("read trace {}", path.display()))?
    };
    let label = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.display().to_string());
    import_named(&text, format, window, &label)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) const BURSTGPT_HEADER: &str =
        "Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type";
    pub(crate) const AZURE_HEADER: &str = "TIMESTAMP,ContextTokens,GeneratedTokens";

    fn burst(rows: &[&str]) -> String {
        let mut s = String::from(BURSTGPT_HEADER);
        for r in rows {
            s.push('\n');
            s.push_str(r);
        }
        s
    }

    #[test]
    fn burstgpt_rows_map_log_types_to_classes() {
        let text = burst(&[
            "10,ChatGPT,100,50,150,Conversation log",
            "12,GPT-4,30,7,37,API log",
            "15,ChatGPT,200,80,280,Conversation log",
        ]);
        let t = import_named(&text, TraceFormat::BurstGpt, 5.0, "b.csv").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.duration(), 5.0); // 15 - 10, rebased
        assert_eq!(t.classes().len(), 2);
        assert_eq!(t.classes()[0].name, "conversation");
        assert_eq!(t.classes()[1].name, "api");
        assert_eq!(t.classes()[1].dataset.name, "Alpaca-gpt4");
        assert_eq!(t.class_counts(), vec![2, 1]);
        let rec = &t.records()[1];
        assert_eq!((rec.arrival, rec.input_len, rec.output_len, rec.class), (2.0, 30, 7, 1));
        assert_eq!(t.source(), "b.csv");
        assert_eq!(t.lineage(), Some("burstgpt import of 'b.csv' (3 requests)"));
    }

    #[test]
    fn unused_classes_are_compacted_away() {
        // An all-API slice: the conversation class must not survive as a
        // phantom zero-arrival row.
        let text = burst(&["10,GPT-4,30,7,37,API log", "12,GPT-4,31,8,39,API log"]);
        let t = import_named(&text, TraceFormat::BurstGpt, 5.0, "api.csv").unwrap();
        assert_eq!(t.classes().len(), 1);
        assert_eq!(t.classes()[0].name, "api");
        assert_eq!(t.class_counts(), vec![2]);
        assert_eq!(t.records()[0].class, 0);
    }

    #[test]
    fn azure_rows_parse_both_timestamp_forms() {
        let text = format!(
            "{AZURE_HEADER}\n\
             2023-11-16 18:13:01.50,100,40\n\
             2023-11-16 18:13:03,200,60\n\
             2023-11-16 18:14:00,50,10"
        );
        let t = import_named(&text, TraceFormat::Azure, 5.0, "a.csv").unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.classes().len(), 1);
        assert_eq!(t.classes()[0].name, "azure-llm");
        assert_eq!(t.records()[0].arrival, 0.0);
        assert_eq!(t.records()[1].arrival, 1.5);
        assert_eq!(t.duration(), 58.5);

        // Plain float-seconds timestamps work too.
        let text = format!("{AZURE_HEADER}\n0.5,100,40\n2.25,200,60");
        let t = import_named(&text, TraceFormat::Azure, 5.0, "a.csv").unwrap();
        assert_eq!(t.records()[1].arrival, 1.75);
    }

    #[test]
    fn malformed_rows_error_with_line_numbers() {
        // Wrong header.
        let e = fmt_err(import_named("nope,nope\n", TraceFormat::BurstGpt, 5.0, "x.csv"));
        assert!(e.contains("x.csv:1") && e.contains("header"), "{e}");
        // Wrong column count.
        let e = fmt_err(import_named(
            &burst(&["10,ChatGPT,100,50,150"]),
            TraceFormat::BurstGpt,
            5.0,
            "x.csv",
        ));
        assert!(e.contains("x.csv:2") && e.contains("6"), "{e}");
        // Zero-token rows.
        let e = fmt_err(import_named(
            &burst(&["10,ChatGPT,0,50,50,API log"]),
            TraceFormat::BurstGpt,
            5.0,
            "x.csv",
        ));
        assert!(e.contains("x.csv:2") && e.contains("zero-token"), "{e}");
        // Unknown log type.
        let e = fmt_err(import_named(
            &burst(&["10,ChatGPT,1,1,2,Batch log"]),
            TraceFormat::BurstGpt,
            5.0,
            "x.csv",
        ));
        assert!(e.contains("x.csv:2") && e.contains("Log Type"), "{e}");
        // Azure: bad timestamp.
        let e = fmt_err(import_named(
            &format!("{AZURE_HEADER}\n2023-13-40 99:99:99,1,1"),
            TraceFormat::Azure,
            5.0,
            "a.csv",
        ));
        assert!(e.contains("a.csv:2") && e.contains("TIMESTAMP"), "{e}");
        // Empty data section.
        let e = fmt_err(import_named(BURSTGPT_HEADER, TraceFormat::BurstGpt, 5.0, "x.csv"));
        assert!(e.contains("empty trace"), "{e}");
    }

    #[test]
    fn reorder_inside_the_window_sorts_beyond_it_errors() {
        // 12 arrives before 10: 2 s behind max-seen, inside a 5 s window.
        let ok = burst(&[
            "12,ChatGPT,1,1,2,API log",
            "10,ChatGPT,2,2,4,API log",
            "13,ChatGPT,3,3,6,API log",
        ]);
        let t = import_named(&ok, TraceFormat::BurstGpt, 5.0, "ok.csv").unwrap();
        let inputs: Vec<usize> = t.records().iter().map(|r| r.input_len).collect();
        assert_eq!(inputs, vec![2, 1, 3]);
        assert_eq!(t.records()[0].arrival, 0.0);

        // 10 is 50 s behind 60: beyond the window, strict line-numbered error.
        let bad = burst(&["60,ChatGPT,1,1,2,API log", "10,ChatGPT,2,2,4,API log"]);
        let e = fmt_err(import_named(&bad, TraceFormat::BurstGpt, 5.0, "bad.csv"));
        assert!(e.contains("bad.csv:3") && e.contains("reorder window"), "{e}");

        // Equal timestamps keep line order (the stable tie-break).
        let ties = burst(&[
            "10,ChatGPT,1,1,2,API log",
            "10,ChatGPT,2,2,4,API log",
            "11,ChatGPT,3,3,6,API log",
        ]);
        let t = import_named(&ties, TraceFormat::BurstGpt, 0.0, "t.csv").unwrap();
        let inputs: Vec<usize> = t.records().iter().map(|r| r.input_len).collect();
        assert_eq!(inputs, vec![1, 2, 3]);
    }

    #[test]
    fn format_names_resolve_case_insensitively() {
        assert_eq!(TraceFormat::by_name("BurstGPT").unwrap(), TraceFormat::BurstGpt);
        assert_eq!(TraceFormat::by_name("azure").unwrap(), TraceFormat::Azure);
        let e = format!("{:#}", TraceFormat::by_name("mooncake").unwrap_err());
        assert!(e.contains("burstgpt|azure"), "{e}");
    }

    fn fmt_err<T>(r: Result<T>) -> String {
        format!("{:#}", r.unwrap_err())
    }
}
