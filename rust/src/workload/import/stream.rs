//! Streaming replay: bounded-memory arrival sources over external trace
//! files.
//!
//! [`Scanner`] is the single line-level reader both import paths share:
//! it parses rows through the format adapter, tolerates reordering up
//! to the configured window via a small `(timestamp, line)`-ordered
//! heap, and rejects anything older with the offending line number.
//! Because the heap pops the minimum `(timestamp, line)` key and a
//! record may only be released once nothing earlier can still arrive
//! (every unread record is `≥ max_seen − window`, and ties land on
//! later lines), the emission order equals a global stable sort by
//! timestamp — which is exactly what the materialized path produces.
//! One scanner, two consumers, bit-identical replays.
//!
//! [`StreamedTrace`] is the scenario-facing handle: a pre-scan pass
//! ([`StreamedTrace::open`]) validates the whole file and collects the
//! metadata a scenario needs up front (span, request count, per-request
//! class table, class mix); [`StreamedTrace::arrivals_at`] then re-reads
//! the file lazily as a time-warped [`Request`] iterator the cursor
//! engine ([`crate::sim::run_source_faulted`]) consumes directly. Peak
//! memory is the reorder-window buffer plus the engine's active set —
//! never the log length.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;
use std::fmt;
use std::fs::File;
use std::io::{BufRead, BufReader, Cursor, Read};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{compact_classes, import_trace, lineage_for, RawRecord, TraceFormat};
use crate::workload::replay::{ReplayClass, ReplayTrace};
use crate::workload::Request;

/// One record held in the reorder buffer, ordered by `(t, line)` — the
/// same stable tie-break the materialized sort applies.
struct Buffered {
    t: f64,
    line: u64,
    rec: RawRecord,
}

impl Ord for Buffered {
    fn cmp(&self, other: &Self) -> Ordering {
        self.t.total_cmp(&other.t).then(self.line.cmp(&other.line))
    }
}
impl PartialOrd for Buffered {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl PartialEq for Buffered {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Buffered {}

/// Line-level trace reader shared by the materialized and streaming
/// import paths: header check, per-row parsing, and the bounded reorder
/// window. Emits records in global `(timestamp, line)` order or fails
/// with a line-numbered error.
pub(crate) struct Scanner<R: BufRead> {
    lines: std::io::Lines<R>,
    format: TraceFormat,
    window: f64,
    src: String,
    lineno: u64,
    header_done: bool,
    /// Newest timestamp read so far; records older than
    /// `max_seen - window` are rejected, so everything still unread is
    /// provably no earlier than any record the buffer releases.
    max_seen: f64,
    buf: BinaryHeap<Reverse<Buffered>>,
    eof: bool,
    peak_buffered: usize,
}

impl<R: BufRead> Scanner<R> {
    pub(crate) fn new(reader: R, format: TraceFormat, window: f64, src: String) -> Scanner<R> {
        Scanner {
            lines: reader.lines(),
            format,
            window,
            src,
            lineno: 0,
            header_done: false,
            max_seen: f64::NEG_INFINITY,
            buf: BinaryHeap::new(),
            eof: false,
            peak_buffered: 0,
        }
    }

    /// High-water mark of the reorder buffer — the streaming path's
    /// whole memory footprint beyond the engine's active set.
    pub(crate) fn peak_buffered(&self) -> usize {
        self.peak_buffered
    }

    /// The next record in emission order, `Ok(None)` at end of input.
    pub(crate) fn next_emit(&mut self) -> Result<Option<RawRecord>> {
        loop {
            // Release the buffer's minimum once no unread record can
            // precede it (or unconditionally after EOF).
            if let Some(Reverse(top)) = self.buf.peek() {
                if self.eof || top.t <= self.max_seen - self.window {
                    let Reverse(b) = self.buf.pop().expect("peeked non-empty heap");
                    return Ok(Some(b.rec));
                }
            } else if self.eof {
                return Ok(None);
            }
            match self.lines.next() {
                None => self.eof = true,
                Some(line) => {
                    let line = line.with_context(|| format!("read {}", self.src))?;
                    self.lineno += 1;
                    let n = self.lineno as usize;
                    let line = line.strip_suffix('\r').unwrap_or(&line);
                    if !self.header_done {
                        self.format.check_header(line, &self.src)?;
                        self.header_done = true;
                        continue;
                    }
                    if line.trim().is_empty() {
                        bail!("{}:{n}: blank line (one record per line)", self.src);
                    }
                    let rec = self.format.parse_row(line, &self.src, n)?;
                    if rec.t < self.max_seen - self.window {
                        bail!(
                            "{}:{n}: timestamp {} is {:.3}s behind the newest seen \
                             ({}) — beyond the {}s reorder window; sort the trace \
                             or raise the window",
                            self.src,
                            rec.t,
                            self.max_seen - rec.t,
                            self.max_seen,
                            self.window
                        );
                    }
                    if rec.t > self.max_seen {
                        self.max_seen = rec.t;
                    }
                    self.buf.push(Reverse(Buffered { t: rec.t, line: self.lineno, rec }));
                    self.peak_buffered = self.peak_buffered.max(self.buf.len());
                }
            }
        }
    }
}

/// Whether `path` names a gzip-compressed trace (`.gz`, any case).
pub(crate) fn is_gz(path: &Path) -> bool {
    path.extension().map(|e| e.eq_ignore_ascii_case("gz")).unwrap_or(false)
}

/// Open a trace for line scanning, transparently decompressing `.gz`
/// files. Both import paths read through here, so a `.csv.gz` accepts
/// and rejects exactly what its plain `.csv` twin does. Decompression
/// materializes the text (see [`crate::util::gzip`]) — the streaming
/// path's bounded-memory guarantee then bounds everything *beyond* that
/// one decompressed copy.
fn open_reader(path: &Path) -> Result<Box<dyn BufRead + Send>> {
    let file = File::open(path).with_context(|| format!("open trace {}", path.display()))?;
    if is_gz(path) {
        let mut raw = Vec::new();
        BufReader::new(file)
            .read_to_end(&mut raw)
            .with_context(|| format!("read trace {}", path.display()))?;
        let text = crate::util::gzip::gunzip(&raw)
            .map_err(|e| anyhow::anyhow!("decompress {}: {e}", path.display()))?;
        Ok(Box::new(Cursor::new(text)))
    } else {
        Ok(Box::new(BufReader::new(file)))
    }
}

fn file_label(path: &Path) -> String {
    path.file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| path.display().to_string())
}

/// A validated external trace consumed lazily from disk: all the
/// metadata of a [`ReplayTrace`] (span, rate, classes, per-request class
/// attribution) without the record vector. Cheap to clone — the heavy
/// part is the shared class table, one byte per request.
#[derive(Clone)]
pub struct StreamedTrace {
    path: PathBuf,
    format: TraceFormat,
    window: f64,
    /// Display label (file name), like [`ReplayTrace::source`].
    source: String,
    /// Full provenance ([`lineage_for`]), like [`ReplayTrace::lineage`].
    lineage: String,
    /// Compacted class table (unused format classes dropped).
    classes: Vec<ReplayClass>,
    /// Compacted class index per request, in emission order — the
    /// `class_of` side table (ids are the emission index).
    class_table: Arc<Vec<u8>>,
    /// First (minimum) timestamp; arrivals are rebased against it.
    t0: f64,
    /// Recorded span, seconds.
    duration: f64,
    /// Scoring warm-up prefix, seconds (native time).
    warmup: f64,
}

impl fmt::Debug for StreamedTrace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("StreamedTrace")
            .field("source", &self.source)
            .field("format", &self.format.label())
            .field("requests", &self.len())
            .field("classes", &self.classes.len())
            .field("duration_s", &self.duration)
            .field("native_rate", &self.native_rate())
            .finish()
    }
}

impl StreamedTrace {
    /// Pre-scan `path` once: validate every line (strict, line-numbered
    /// errors — a corrupt row must fail at open, not hours into a
    /// replay), and collect span + class metadata. The record stream
    /// itself is not retained; [`StreamedTrace::arrivals_at`] re-reads
    /// the file on demand.
    pub fn open(path: &Path, format: TraceFormat, window: f64) -> Result<StreamedTrace> {
        if !window.is_finite() || window < 0.0 {
            bail!("reorder window must be non-negative and finite, got {window}");
        }
        let label = file_label(path);
        let mut scan = Scanner::new(open_reader(path)?, format, window, label.clone());
        let n_format_classes = format.classes().len();
        assert!(n_format_classes <= u8::MAX as usize + 1);
        let mut t0 = f64::NAN;
        let mut last = f64::NAN;
        let mut table: Vec<u8> = Vec::new();
        while let Some(rec) = scan.next_emit()? {
            if table.is_empty() {
                t0 = rec.t;
            }
            last = rec.t;
            table.push(rec.class as u8);
        }
        if table.is_empty() {
            bail!("{label}: empty trace — no records to replay");
        }
        let duration = last - t0;
        if duration <= 0.0 {
            bail!("{label}: trace spans zero seconds — need at least two distinct timestamps");
        }
        let mut used = vec![false; n_format_classes];
        for &c in &table {
            used[c as usize] = true;
        }
        let (classes, remap) = compact_classes(format.classes(), &used);
        for c in table.iter_mut() {
            *c = remap[*c as usize] as u8;
        }
        let lineage = lineage_for(format, &label, table.len());
        let warmup = (duration / 8.0).min(30.0); // assemble()'s rule
        Ok(StreamedTrace {
            path: path.to_path_buf(),
            format,
            window,
            source: label,
            lineage,
            classes,
            class_table: Arc::new(table),
            t0,
            duration,
            warmup,
        })
    }

    // ---- accessors (mirroring ReplayTrace) ------------------------------

    pub fn len(&self) -> usize {
        self.class_table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.class_table.is_empty()
    }

    pub fn classes(&self) -> &[ReplayClass] {
        &self.classes
    }

    pub fn duration(&self) -> f64 {
        self.duration
    }

    pub fn warmup(&self) -> f64 {
        self.warmup
    }

    pub fn source(&self) -> &str {
        &self.source
    }

    /// Full provenance string (format, file, request count).
    pub fn lineage(&self) -> &str {
        &self.lineage
    }

    pub fn format(&self) -> TraceFormat {
        self.format
    }

    pub fn window(&self) -> f64 {
        self.window
    }

    /// Time-averaged offered rate of the recorded log, req/s.
    pub fn native_rate(&self) -> f64 {
        self.class_table.len() as f64 / self.duration
    }

    /// Class of replayed request `id` (ids are the emission index).
    pub fn class_of(&self, id: u64) -> usize {
        self.class_table[id as usize] as usize
    }

    /// Requests per class, whole log.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes.len().max(1)];
        for &c in self.class_table.iter() {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Lazy time-warped replay: the streaming equivalent of
    /// [`ReplayTrace::requests_at`], identical float-for-float (same
    /// rebase, same warp expression, same horizon clip, same ids), but
    /// reading the file as the engine consumes it. Fails only on I/O —
    /// the pre-scan already validated content.
    pub fn arrivals_at(&self, rate: f64, horizon: f64) -> Result<StreamedArrivals> {
        let scan =
            Scanner::new(open_reader(&self.path)?, self.format, self.window, self.source.clone());
        // Same degenerate-rate clamp as ReplayTrace::requests_at.
        let warp = self.native_rate() / rate.max(1e-9);
        Ok(StreamedArrivals { scan, t0: self.t0, warp, horizon, next_id: 0, done: false })
    }

    /// Materialize through [`import_trace`] — by construction the exact
    /// trace the one-shot path builds, for differential tests and small
    /// logs.
    pub fn materialize(&self) -> Result<ReplayTrace> {
        import_trace(&self.path, self.format, self.window)
    }
}

/// Bounded-memory [`Request`] iterator over a [`StreamedTrace`]: feed it
/// to [`crate::sim::run_source_faulted`] via `&mut` so
/// [`StreamedArrivals::peak_buffered`] stays readable after the run.
/// Mid-iteration errors panic: the pre-scan validated the file, so they
/// mean it changed (or vanished) between open and replay, and silently
/// truncating the workload would corrupt the measurement.
pub struct StreamedArrivals {
    scan: Scanner<Box<dyn BufRead + Send>>,
    t0: f64,
    warp: f64,
    horizon: f64,
    next_id: u64,
    done: bool,
}

impl StreamedArrivals {
    /// High-water mark of the reorder buffer during this replay.
    pub fn peak_buffered(&self) -> usize {
        self.scan.peak_buffered()
    }
}

impl Iterator for StreamedArrivals {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.done {
            return None;
        }
        let rec = match self.scan.next_emit() {
            Ok(Some(rec)) => rec,
            Ok(None) => {
                self.done = true;
                return None;
            }
            Err(e) => panic!("streamed replay failed mid-run (trace changed since open?): {e:#}"),
        };
        let arrival = (rec.t - self.t0) * self.warp;
        if arrival > self.horizon {
            // Sorted emission: every later record is beyond the horizon
            // too, so stop reading the file entirely.
            self.done = true;
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        Some(Request { id, arrival, input_len: rec.input_len, output_len: rec.output_len })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_temp(name: &str, text: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ecoserve-import-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(text.as_bytes()).unwrap();
        path
    }

    fn burst_text(n: usize) -> String {
        let mut s = String::from(
            "Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type",
        );
        for i in 0..n {
            let class = if i % 3 == 0 { "API log" } else { "Conversation log" };
            s.push_str(&format!(
                "\n{},ChatGPT,{},{},{},{class}",
                i / 2, // two requests per second
                100 + i,
                10 + i % 7,
                110 + i + i % 7,
            ));
        }
        s
    }

    #[test]
    fn streamed_open_collects_the_same_metadata_as_materialize() {
        let path = write_temp("meta.csv", &burst_text(40));
        let st = StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap();
        let mat = st.materialize().unwrap();
        assert_eq!(st.len(), mat.len());
        assert_eq!(st.duration().to_bits(), mat.duration().to_bits());
        assert_eq!(st.warmup().to_bits(), mat.warmup().to_bits());
        assert_eq!(st.native_rate().to_bits(), mat.native_rate().to_bits());
        assert_eq!(st.source(), mat.source());
        assert_eq!(Some(st.lineage()), mat.lineage());
        assert_eq!(st.classes().len(), mat.classes().len());
        assert_eq!(st.class_counts(), mat.class_counts());
        for id in 0..st.len() as u64 {
            assert_eq!(st.class_of(id), mat.class_of(id));
        }
    }

    #[test]
    fn streamed_arrivals_match_materialized_requests_bit_for_bit() {
        let path = write_temp("bits.csv", &burst_text(60));
        let st = StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap();
        let mat = st.materialize().unwrap();
        for rate in [st.native_rate(), 3.0, 11.5] {
            let horizon = st.duration() * st.native_rate() / rate;
            let want = mat.requests_at(rate, horizon);
            let mut arr = st.arrivals_at(rate, horizon).unwrap();
            let got: Vec<Request> = (&mut arr).collect();
            assert_eq!(got.len(), want.len(), "rate {rate}");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.id, w.id);
                assert_eq!(g.arrival.to_bits(), w.arrival.to_bits());
                assert_eq!(g.input_len, w.input_len);
                assert_eq!(g.output_len, w.output_len);
            }
            assert!(arr.peak_buffered() >= 1);
        }
    }

    #[test]
    fn peak_buffering_is_bounded_by_the_reorder_window_not_log_length() {
        // 2 req/s with a 5 s window: at most ~2*5 + ties can ever sit in
        // the buffer, however long the log runs.
        let path = write_temp("bound.csv", &burst_text(2000));
        let st = StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap();
        let mut arr = st.arrivals_at(st.native_rate(), f64::INFINITY).unwrap();
        assert_eq!((&mut arr).count(), 2000);
        let peak = arr.peak_buffered();
        assert!(peak >= 1 && peak <= 32, "peak {peak} should be window-sized, not 2000");
    }

    #[test]
    fn horizon_clip_stops_reading_early() {
        let path = write_temp("clip.csv", &burst_text(100));
        let st = StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap();
        let mut arr = st.arrivals_at(st.native_rate(), 10.0).unwrap();
        let got: Vec<Request> = (&mut arr).collect();
        // Arrivals at 0,0,0.5,… ≤ 10 s: i/2 ≤ 10 → i ≤ 21 (i/2 is integer
        // seconds here: rows 0..=21 land at ≤ 10 s after the rebase).
        assert!(!got.is_empty() && got.len() < 100);
        assert!(got.iter().all(|r| r.arrival <= 10.0));
        // Exhausted iterators stay exhausted.
        assert_eq!(arr.next(), None);
    }

    #[test]
    fn open_rejects_what_the_materialized_path_rejects() {
        let bad = "Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type\n\
                   60,ChatGPT,1,1,2,API log\n\
                   10,ChatGPT,2,2,4,API log";
        let path = write_temp("bad.csv", bad);
        let e = format!(
            "{:#}",
            StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap_err()
        );
        assert!(e.contains("bad.csv:3") && e.contains("reorder window"), "{e}");
        let e = format!(
            "{:#}",
            StreamedTrace::open(Path::new("/no/such/file.csv"), TraceFormat::Azure, 5.0)
                .unwrap_err()
        );
        assert!(e.contains("file.csv"), "{e}");
        let e = format!(
            "{:#}",
            StreamedTrace::open(&path, TraceFormat::BurstGpt, f64::NAN).unwrap_err()
        );
        assert!(e.contains("reorder window"), "{e}");
    }
}
