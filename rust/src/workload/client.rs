//! Closed-loop client model: per-request TTFT timeouts, a bounded retry
//! budget, and exponential backoff with seeded deterministic jitter.
//!
//! The open-loop engine assumes demand is infinitely patient; real
//! clients are not. Each logical request gets a timer armed at arrival:
//! if the first token hasn't been served when it fires, the client gives
//! up on that attempt and — budget permitting — re-submits the request
//! after a jittered exponential backoff. Coordinator rejections produce
//! the same retry path immediately (fast error feedback), which is
//! exactly the retry-storm amplification loop that turns saturation into
//! congestion collapse on undefended systems.
//!
//! Determinism: the jitter RNG is a dedicated [`Pcg64`] stream keyed by
//! the policy seed, retry ids are allocated from a private counter above
//! [`RETRY_ID_BASE`], and every client action rides the engine's
//! (time, seq)-ordered heap — so client-in-the-loop runs are reproducible
//! bit-for-bit, and runs without a client are untouched (the engine only
//! consults the client when one is supplied).

use std::collections::HashMap;

use crate::metrics::Collector;
use crate::sim::{Event, EventScheduler};
use crate::util::rng::Pcg64;
use crate::workload::Request;

/// Retry attempts get fresh ids at or above this base so scoring can
/// separate logical (trace) requests from client re-submissions: goodput
/// and attainment stay anchored on first-attempt outcomes, retries act
/// purely as load amplification.
pub const RETRY_ID_BASE: u64 = 1 << 62;

/// Dedicated PCG stream for client backoff jitter (the fault scheduler
/// uses 0xFA17; disjoint streams keep the two schedules independent).
const CLIENT_JITTER_STREAM: u64 = 0xC11E47;

/// The closed-loop client behavior attached to a run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientPolicy {
    /// Seconds the client waits for the first token before abandoning an
    /// attempt. The scenario driver clamps this to at least the loosest
    /// per-class TTFT SLO, so a timed-out attempt is always an SLO
    /// violation — timeouts can never erase a would-have-met request.
    pub timeout_s: f64,
    /// Re-submissions allowed after the initial attempt.
    pub max_retries: u32,
    /// First backoff delay; doubles per retry up to [`Self::backoff_cap_s`].
    pub backoff_base_s: f64,
    pub backoff_cap_s: f64,
    /// Uniform jitter applied to each delay: `delay * U(1-j, 1+j)`.
    pub jitter_frac: f64,
    /// Seed for the jitter stream (independent of the trace seed).
    pub seed: u64,
}

impl ClientPolicy {
    /// A patient production client: generous timeout, three retries.
    pub fn standard() -> Self {
        ClientPolicy {
            timeout_s: 30.0,
            max_retries: 3,
            backoff_base_s: 1.0,
            backoff_cap_s: 8.0,
            jitter_frac: 0.2,
            seed: 0xC11E,
        }
    }

    /// An impatient flash-crowd client: tight timeout, eager retries with
    /// short backoff — the retry-storm ingredient.
    pub fn aggressive() -> Self {
        ClientPolicy {
            timeout_s: 12.0,
            max_retries: 4,
            backoff_base_s: 0.25,
            backoff_cap_s: 2.0,
            jitter_frac: 0.3,
            seed: 0xC11E,
        }
    }
}

/// What the client loop observed over a run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientTelemetry {
    /// Attempts abandoned because the first token missed the timeout.
    pub timeouts: u64,
    /// Attempts that got fast rejection feedback from the coordinator.
    pub rejected: u64,
    /// Re-submissions scheduled (timeouts + rejections that had budget).
    pub retries: u64,
    /// Logical requests whose retry budget ran out.
    pub gave_up: u64,
    /// Attempts resolved in time (first token before the timer fired).
    pub succeeded: u64,
}

#[derive(Debug, Clone, Copy)]
struct Attempt {
    /// Retries consumed so far for this logical request (0 = original).
    tries: u32,
    input_len: usize,
    output_len: usize,
}

/// Per-run client state: attempt table, jitter RNG, telemetry. Owned by
/// the caller and handed to the engine's `_client` entry points by
/// mutable reference; read the telemetry back after the run.
#[derive(Debug)]
pub struct ClientLoop {
    policy: ClientPolicy,
    rng: Pcg64,
    attempts: HashMap<u64, Attempt>,
    next_retry_id: u64,
    telemetry: ClientTelemetry,
}

impl ClientLoop {
    pub fn new(policy: ClientPolicy) -> Self {
        ClientLoop {
            rng: Pcg64::new(policy.seed, CLIENT_JITTER_STREAM),
            policy,
            attempts: HashMap::new(),
            next_retry_id: RETRY_ID_BASE,
            telemetry: ClientTelemetry::default(),
        }
    }

    pub fn telemetry(&self) -> ClientTelemetry {
        self.telemetry
    }

    /// An arrival was dispatched (trace request or one of our retries):
    /// arm its TTFT timer.
    pub fn on_arrival(&mut self, req: &Request, sched: &mut EventScheduler) {
        self.attempts.entry(req.id).or_insert(Attempt {
            tries: 0,
            input_len: req.input_len,
            output_len: req.output_len,
        });
        sched.at(req.arrival + self.policy.timeout_s, Event::ClientCheck { id: req.id });
    }

    /// The TTFT timer for `id` fired: success if the first token was
    /// served (or the request already completed), timeout otherwise.
    pub fn on_check(
        &mut self,
        id: u64,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &Collector,
    ) {
        let Some(&attempt) = self.attempts.get(&id) else {
            return; // already resolved (e.g. rejected and re-submitted)
        };
        match metrics.first_token_pending(id) {
            Some(true) => {
                // Still queued past the deadline: the client walks away.
                // The abandoned attempt keeps occupying the server — that
                // wasted work is the congestion-collapse mechanism.
                self.attempts.remove(&id);
                self.telemetry.timeouts += 1;
                self.schedule_retry(attempt, now, sched);
            }
            Some(false) | None => {
                self.attempts.remove(&id);
                self.telemetry.succeeded += 1;
            }
        }
    }

    /// Fast feedback: the coordinator rejected `id` at admission.
    pub fn on_reject(&mut self, id: u64, now: f64, sched: &mut EventScheduler) {
        let Some(attempt) = self.attempts.remove(&id) else {
            return;
        };
        self.telemetry.rejected += 1;
        self.schedule_retry(attempt, now, sched);
    }

    fn schedule_retry(&mut self, attempt: Attempt, now: f64, sched: &mut EventScheduler) {
        if attempt.tries >= self.policy.max_retries {
            self.telemetry.gave_up += 1;
            return;
        }
        let tries = attempt.tries + 1;
        let backoff = (self.policy.backoff_base_s * 2f64.powi(tries as i32 - 1))
            .min(self.policy.backoff_cap_s);
        let j = self.policy.jitter_frac;
        let delay = backoff * self.rng.uniform(1.0 - j, 1.0 + j);
        let at = now + delay;
        let rid = self.next_retry_id;
        self.next_retry_id += 1;
        self.attempts.insert(
            rid,
            Attempt { tries, input_len: attempt.input_len, output_len: attempt.output_len },
        );
        self.telemetry.retries += 1;
        sched.at(
            at,
            Event::Arrival(Request {
                id: rid,
                arrival: at,
                input_len: attempt.input_len,
                output_len: attempt.output_len,
            }),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, arrival, input_len: 64, output_len: 16 }
    }

    #[test]
    fn timer_is_armed_per_arrival() {
        let mut c = ClientLoop::new(ClientPolicy::standard());
        let mut sched = EventScheduler::new();
        c.on_arrival(&req(1, 0.0), &mut sched);
        c.on_arrival(&req(2, 5.0), &mut sched);
        assert_eq!(sched.len(), 2);
        // Re-dispatching the same arrival arms a second timer but must
        // not reset the attempt's retry count (entry or_insert).
        c.on_arrival(&req(1, 0.0), &mut sched);
        assert_eq!(sched.len(), 3);
    }

    #[test]
    fn timeout_schedules_a_retry_with_a_fresh_high_id() {
        let mut c = ClientLoop::new(ClientPolicy::standard());
        let mut sched = EventScheduler::new();
        let mut metrics = Collector::new();
        let r = req(1, 0.0);
        metrics.on_arrival(&r);
        c.on_arrival(&r, &mut sched);
        // Timer fires with the first token still pending: one retry
        // arrival joins the heap (plus the original timer already there).
        c.on_check(1, 30.0, &mut sched, &metrics);
        let t = c.telemetry();
        assert_eq!(t.timeouts, 1);
        assert_eq!(t.retries, 1);
        assert_eq!(t.succeeded, 0);
        assert_eq!(sched.len(), 2);
    }

    #[test]
    fn served_first_token_resolves_without_retry() {
        let mut c = ClientLoop::new(ClientPolicy::standard());
        let mut sched = EventScheduler::new();
        let mut metrics = Collector::new();
        let r = req(1, 0.0);
        metrics.on_arrival(&r);
        metrics.on_first_token(1, 0.5);
        c.on_arrival(&r, &mut sched);
        c.on_check(1, 30.0, &mut sched, &metrics);
        let t = c.telemetry();
        assert_eq!(t.succeeded, 1);
        assert_eq!(t.retries, 0);
        // Completion before the timer is success too.
        let r2 = req(2, 1.0);
        metrics.on_arrival(&r2);
        metrics.on_first_token(2, 1.2);
        metrics.on_complete(2, 2.0);
        c.on_arrival(&r2, &mut sched);
        c.on_check(2, 31.0, &mut sched, &metrics);
        assert_eq!(c.telemetry().succeeded, 2);
    }

    #[test]
    fn retry_budget_is_bounded_and_backoff_grows() {
        let policy = ClientPolicy {
            timeout_s: 1.0,
            max_retries: 2,
            backoff_base_s: 1.0,
            backoff_cap_s: 100.0,
            jitter_frac: 0.0, // deterministic delays for the assertion
            seed: 9,
        };
        let mut c = ClientLoop::new(policy);
        let mut sched = EventScheduler::new();
        let mut metrics = Collector::new();
        let r = req(1, 0.0);
        metrics.on_arrival(&r);
        c.on_arrival(&r, &mut sched);
        // First timeout: retry #1 at now + 1.0 (tries=1, backoff 2^0).
        c.on_check(1, 1.0, &mut sched, &metrics);
        // The retry arrival fires; arm it, then time it out as well:
        // retry #2 at now + 2.0 (tries=2, backoff 2^1).
        let rid1 = RETRY_ID_BASE;
        let retry1 = req(rid1, 2.0);
        metrics.on_arrival(&retry1);
        c.on_arrival(&retry1, &mut sched);
        c.on_check(rid1, 3.0, &mut sched, &metrics);
        // Budget exhausted: the third timeout gives up instead.
        let rid2 = RETRY_ID_BASE + 1;
        let retry2 = req(rid2, 5.0);
        metrics.on_arrival(&retry2);
        c.on_arrival(&retry2, &mut sched);
        c.on_check(rid2, 6.0, &mut sched, &metrics);
        let t = c.telemetry();
        assert_eq!(t.timeouts, 3);
        assert_eq!(t.retries, 2);
        assert_eq!(t.gave_up, 1);
    }

    #[test]
    fn rejection_feedback_retries_immediately_with_backoff() {
        let mut c = ClientLoop::new(ClientPolicy::standard());
        let mut sched = EventScheduler::new();
        let r = req(1, 0.0);
        c.on_arrival(&r, &mut sched);
        c.on_reject(1, 0.0, &mut sched);
        let t = c.telemetry();
        assert_eq!(t.rejected, 1);
        assert_eq!(t.retries, 1);
        // Rejecting an id the client never saw (or already resolved) is
        // a no-op — systems may reject requests with no client attached.
        c.on_reject(999, 1.0, &mut sched);
        assert_eq!(c.telemetry().rejected, 1);
    }

    #[test]
    fn retry_ids_are_disjoint_from_trace_ids() {
        let mut c = ClientLoop::new(ClientPolicy::standard());
        let mut sched = EventScheduler::new();
        for id in 0..4 {
            c.on_arrival(&req(id, 0.0), &mut sched);
            c.on_reject(id, 0.0, &mut sched);
        }
        let t = c.telemetry();
        assert_eq!(t.retries, 4);
        // Four retries allocated RETRY_ID_BASE..RETRY_ID_BASE+4; a fifth
        // logical request can never collide with them.
        assert!(RETRY_ID_BASE > u32::MAX as u64);
    }
}
