//! Synthetic length-distribution models fitted to the paper's Table 4.
//!
//! | Dataset     | In avg | In med | Out avg | Out med | TTFT SLO | TPOT SLO |
//! |-------------|--------|--------|---------|---------|----------|----------|
//! | Alpaca-gpt4 | 20.63  | 17.00  | 163.80  | 119.00  | 1 s      | 100 ms   |
//! | ShareGPT    | 343.76 | 148.00 | 237.20  | 152.00  | 5 s      | 100 ms   |
//! | LongBench   | 2686.89| 2736.50| 101.78  | 19.00   | 15 s     | 100 ms   |
//!
//! Right-skewed columns (mean > median) are log-normal with mu = ln(median)
//! and sigma = sqrt(2·ln(mean/median)) — the moment-matching fit. LongBench
//! inputs have mean < median (left-skewed by the paper's truncation at 4096)
//! and use a clamped normal instead. All draws are truncated to the paper's
//! [1, 4096] input / [1, 2048] output ranges.

use crate::util::rng::Pcg64;

/// A fitted marginal distribution over token lengths.
#[derive(Debug, Clone, PartialEq)]
pub enum LengthModel {
    /// Log-normal with underlying (mu, sigma), clamped to [min, max].
    LogNormal { mu: f64, sigma: f64, min: usize, max: usize },
    /// Normal(mean, std) clamped to [min, max] (for left-skewed columns).
    Normal { mean: f64, std: f64, min: usize, max: usize },
    /// Every request identical — unit tests and microbenches.
    Fixed(usize),
}

impl LengthModel {
    /// Moment-matched log-normal from a (mean, median) pair.
    pub fn lognormal_from_moments(mean: f64, median: f64, min: usize, max: usize) -> Self {
        assert!(mean >= median, "lognormal fit needs mean >= median");
        let mu = median.ln();
        let sigma = (2.0 * (mean / median).ln()).sqrt();
        LengthModel::LogNormal { mu, sigma, min, max }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        match *self {
            LengthModel::LogNormal { mu, sigma, min, max } => {
                let x = rng.lognormal(mu, sigma);
                (x.round() as usize).clamp(min, max)
            }
            LengthModel::Normal { mean, std, min, max } => {
                let x = rng.normal_with(mean, std);
                (x.round().max(1.0) as usize).clamp(min, max)
            }
            LengthModel::Fixed(n) => n,
        }
    }

    /// Analytic mean of the *untruncated* model (truncation shifts it
    /// slightly; tests allow the tolerance).
    pub fn untruncated_mean(&self) -> f64 {
        match *self {
            LengthModel::LogNormal { mu, sigma, .. } => (mu + sigma * sigma / 2.0).exp(),
            LengthModel::Normal { mean, .. } => mean,
            LengthModel::Fixed(n) => n as f64,
        }
    }
}

/// A dataset = input/output length models + the paper's SLO pair.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    pub name: &'static str,
    pub input: LengthModel,
    pub output: LengthModel,
    /// TTFT SLO, seconds (paper Table 4; includes phase-switching wait,
    /// §3.3's stricter definition).
    pub slo_ttft: f64,
    /// TPOT SLO, seconds.
    pub slo_tpot: f64,
}

impl Dataset {
    /// Human-instruction workload: short prompts, long outputs.
    pub fn alpaca() -> Self {
        Dataset {
            name: "Alpaca-gpt4",
            input: LengthModel::lognormal_from_moments(20.63, 17.0, 1, 4096),
            output: LengthModel::lognormal_from_moments(163.8, 119.0, 1, 2048),
            slo_ttft: 1.0,
            slo_tpot: 0.1,
        }
    }

    /// Chatbot workload: balanced prompt/output lengths.
    pub fn sharegpt() -> Self {
        Dataset {
            name: "ShareGPT",
            input: LengthModel::lognormal_from_moments(343.76, 148.0, 1, 4096),
            output: LengthModel::lognormal_from_moments(237.2, 152.0, 1, 2048),
            slo_ttft: 5.0,
            slo_tpot: 0.1,
        }
    }

    /// Summarization workload: long prompts, short outputs. Inputs are
    /// left-skewed (paper truncates at 4096), hence the clamped normal.
    pub fn longbench() -> Self {
        Dataset {
            name: "LongBench",
            input: LengthModel::Normal { mean: 2736.5, std: 900.0, min: 64, max: 4096 },
            output: LengthModel::lognormal_from_moments(101.78, 19.0, 1, 2048),
            slo_ttft: 15.0,
            slo_tpot: 0.1,
        }
    }

    /// Tiny-range dataset for the live path (TinyLM max_seq is 128).
    pub fn tiny() -> Self {
        Dataset {
            name: "Tiny",
            input: LengthModel::LogNormal { mu: 2.7, sigma: 0.6, min: 2, max: 48 },
            output: LengthModel::LogNormal { mu: 2.3, sigma: 0.7, min: 2, max: 64 },
            slo_ttft: 2.0,
            slo_tpot: 0.5,
        }
    }

    pub fn by_name(name: &str) -> Option<Dataset> {
        match name.to_ascii_lowercase().as_str() {
            "alpaca" | "alpaca-gpt4" => Some(Self::alpaca()),
            "sharegpt" => Some(Self::sharegpt()),
            "longbench" => Some(Self::longbench()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    pub fn all_paper() -> Vec<Dataset> {
        vec![Self::alpaca(), Self::sharegpt(), Self::longbench()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn sample_stats(m: &LengthModel, n: usize) -> (f64, f64) {
        let mut rng = Pcg64::seeded(1234);
        let mut xs: Vec<f64> = (0..n).map(|_| m.sample(&mut rng) as f64).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = xs.iter().sum::<f64>() / n as f64;
        (mean, xs[n / 2])
    }

    #[test]
    fn alpaca_moments_match_table4() {
        let d = Dataset::alpaca();
        let (mean_in, med_in) = sample_stats(&d.input, 200_000);
        assert!((mean_in - 20.63).abs() < 1.5, "mean_in={mean_in}");
        assert!((med_in - 17.0).abs() < 2.0, "med_in={med_in}");
        let (mean_out, med_out) = sample_stats(&d.output, 200_000);
        assert!((mean_out - 163.8).abs() / 163.8 < 0.08, "mean_out={mean_out}");
        assert!((med_out - 119.0).abs() / 119.0 < 0.08, "med_out={med_out}");
    }

    #[test]
    fn sharegpt_moments_match_table4() {
        let d = Dataset::sharegpt();
        let (mean_in, med_in) = sample_stats(&d.input, 200_000);
        // Truncation at 4096 clips the fat right tail a little.
        assert!((mean_in - 343.76).abs() / 343.76 < 0.15, "mean_in={mean_in}");
        assert!((med_in - 148.0).abs() / 148.0 < 0.08, "med_in={med_in}");
    }

    #[test]
    fn longbench_moments_match_table4() {
        let d = Dataset::longbench();
        let (mean_in, med_in) = sample_stats(&d.input, 100_000);
        assert!((mean_in - 2686.9).abs() / 2686.9 < 0.1, "mean_in={mean_in}");
        assert!((med_in - 2736.5).abs() / 2736.5 < 0.1, "med_in={med_in}");
        let (mean_out, med_out) = sample_stats(&d.output, 100_000);
        assert!((med_out - 19.0).abs() < 4.0, "med_out={med_out}");
        assert!((mean_out - 101.78).abs() / 101.78 < 0.25, "mean_out={mean_out}");
    }

    #[test]
    fn all_samples_within_bounds() {
        let mut rng = Pcg64::seeded(7);
        for d in Dataset::all_paper() {
            for _ in 0..10_000 {
                let i = d.input.sample(&mut rng);
                let o = d.output.sample(&mut rng);
                assert!((1..=4096).contains(&i), "{} input {i}", d.name);
                assert!((1..=2048).contains(&o), "{} output {o}", d.name);
            }
        }
    }

    #[test]
    fn slos_match_table4() {
        assert_eq!(Dataset::alpaca().slo_ttft, 1.0);
        assert_eq!(Dataset::sharegpt().slo_ttft, 5.0);
        assert_eq!(Dataset::longbench().slo_ttft, 15.0);
        for d in Dataset::all_paper() {
            assert_eq!(d.slo_tpot, 0.1);
        }
    }

    #[test]
    fn lookup_and_fixed() {
        assert!(Dataset::by_name("ShareGPT").is_some());
        assert!(Dataset::by_name("imagenet").is_none());
        let mut rng = Pcg64::seeded(1);
        assert_eq!(LengthModel::Fixed(42).sample(&mut rng), 42);
    }
}
