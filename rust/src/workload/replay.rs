//! Trace replay: recorded arrival logs as first-class workloads.
//!
//! The scenario suite's six load shapes are synthetic. Real evaluations
//! (DistServe arXiv:2401.09670, DynaServe arXiv:2504.09285) replay
//! recorded production arrival logs — ShareGPT/BurstGPT-style traces of
//! `(arrival time, input length, output length)` — so the measured
//! frontier reflects traffic a fleet actually saw. This module parses
//! that log format into the same [`Request`] stream every synthetic
//! shape produces, and writes it back out (`ecoserve record`), so any
//! scenario round-trips through the wire format.
//!
//! ## Log format (JSONL)
//!
//! One JSON object per line. The first line MAY be a header:
//!
//! ```text
//! {"ecoserve_trace":1,"duration_s":300,"warmup_s":30,"source":"...",
//!  "classes":[{"name":"chat","dataset":"sharegpt"}]}
//! {"arrival_s":0.023,"input_len":61,"output_len":1027,"class":0}
//! {"arrival_s":0.026,"input_len":54,"output_len":45,"class":0}
//! ```
//!
//! Every other line is a record: `arrival_s` (seconds from trace start),
//! `input_len`/`output_len` (tokens), and an optional `class` index into
//! the header's class table (default 0). Headerless logs are accepted:
//! classes are then inferred from the largest index seen and scored
//! against ShareGPT SLOs, and the horizon is the last arrival.
//!
//! Parsing is strict: blank or malformed lines, non-finite arrivals,
//! zero lengths, out-of-range class indices, and arrivals beyond a
//! declared `duration_s` all fail with the offending line number —
//! silently skipping a corrupt line would silently change the workload.
//!
//! ## Time-warp rescaling
//!
//! The frontier search needs a `rate` knob. [`ReplayTrace::requests_at`]
//! uniformly rescales inter-arrival gaps (equivalently: all arrival
//! times) by `native_rate / rate`, leaving lengths untouched, so the
//! time-averaged offered rate over the replayed span equals the probe
//! rate while burst *structure* is preserved. At the native rate the
//! warp factor is exactly 1.0 and the replay is bit-for-bit the
//! recorded trace.

use std::fmt;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::datasets::Dataset;
use super::Request;
use crate::util::json::Json;

/// Version tag of the log header (`"ecoserve_trace"` key).
pub const FORMAT_VERSION: f64 = 1.0;

/// Cap on class indices a *headerless* log may use (header-declared
/// class tables carry their own exact bound). Class synthesis allocates
/// `max_class + 1` entries, so an unbounded index in one corrupt record
/// would turn into a giant allocation instead of a parse error.
pub const MAX_INFERRED_CLASSES: usize = 64;

/// Cap on `--loop` tiling copies ([`ReplayTrace::tiled`]): a horizon
/// large enough to exceed this is a typo (`--loop 1e30`), and an
/// uncapped copy count would allocate `repeats × len` requests.
pub const MAX_TILE_REPEATS: usize = 10_000;

/// Leak a small string into a `&'static str`. Replay class and scenario
/// names feed APIs built around `&'static str` registry literals; logs
/// are loaded O(1) times per process, so the leak is bounded and cheap.
pub(crate) fn leak(s: String) -> &'static str {
    Box::leak(s.into_boxed_str())
}

/// One traffic class declared by a log header (or synthesized for
/// headerless logs): the SLO pair comes from the named dataset.
#[derive(Debug, Clone)]
pub struct ReplayClass {
    pub name: &'static str,
    pub dataset: Dataset,
}

/// One parsed log record, in native (un-warped) time.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayRecord {
    /// Seconds from trace start.
    pub arrival: f64,
    /// Prompt tokens.
    pub input_len: usize,
    /// Generation tokens (the oracle value, as in [`Request`]).
    pub output_len: usize,
    /// Index into the class table.
    pub class: usize,
}

/// A parsed arrival log: records sorted by `(arrival, line order)` — the
/// same tie-break [`crate::scenarios::Scenario::build_trace`] applies to
/// merged synthetic streams — plus the class table and horizon.
#[derive(Clone)]
pub struct ReplayTrace {
    records: Vec<ReplayRecord>,
    classes: Vec<ReplayClass>,
    /// Recorded span, seconds (header `duration_s`, else last arrival).
    duration: f64,
    /// Scoring warm-up prefix, seconds (header `warmup_s`, else derived).
    warmup: f64,
    /// Short label for reports ("inline", a file name, ...).
    source: String,
    /// Full provenance string from the log header's `source` field
    /// (scenario/seed/rate for recorded logs, upstream trace identity for
    /// imported ones). Distinct from the display label above so a replay
    /// report can say "replay_mixed.jsonl" while the wire format carries
    /// the whole lineage; `render` writes this back, so record → import →
    /// record round-trips preserve it.
    lineage: Option<String>,
}

impl fmt::Debug for ReplayTrace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ReplayTrace")
            .field("source", &self.source)
            .field("requests", &self.records.len())
            .field("classes", &self.classes.len())
            .field("duration_s", &self.duration)
            .field("native_rate", &self.native_rate())
            .finish()
    }
}

/// Header fields recognized on line 1.
struct Header {
    duration: Option<f64>,
    warmup: Option<f64>,
    classes: Option<Vec<ReplayClass>>,
    lineage: Option<String>,
}

fn parse_header(j: &Json, src: &str) -> Result<Header> {
    let version = j
        .get("ecoserve_trace")
        .and_then(|v| v.as_f64())
        .with_context(|| format!("{src}:1: header 'ecoserve_trace' must be a number"))?;
    if version != FORMAT_VERSION {
        bail!("{src}:1: unsupported trace format version {version} (expected {FORMAT_VERSION})");
    }
    let duration = match j.get("duration_s") {
        Some(v) => {
            let d = v
                .as_f64()
                .with_context(|| format!("{src}:1: 'duration_s' must be a number"))?;
            if !d.is_finite() || d <= 0.0 {
                bail!("{src}:1: 'duration_s' must be positive and finite, got {d}");
            }
            Some(d)
        }
        None => None,
    };
    let warmup = match j.get("warmup_s") {
        Some(v) => {
            let w = v
                .as_f64()
                .with_context(|| format!("{src}:1: 'warmup_s' must be a number"))?;
            if !w.is_finite() || w < 0.0 {
                bail!("{src}:1: 'warmup_s' must be non-negative and finite, got {w}");
            }
            Some(w)
        }
        None => None,
    };
    let classes = match j.get("classes") {
        Some(v) => {
            let arr = v
                .as_arr()
                .with_context(|| format!("{src}:1: 'classes' must be an array"))?;
            if arr.is_empty() {
                bail!("{src}:1: 'classes' must not be empty when present");
            }
            let mut out = Vec::with_capacity(arr.len());
            for (k, c) in arr.iter().enumerate() {
                let name = c
                    .get("name")
                    .and_then(|n| n.as_str())
                    .map(|n| n.to_string())
                    .unwrap_or_else(|| format!("class-{k}"));
                let ds_name = c
                    .get("dataset")
                    .and_then(|d| d.as_str())
                    .unwrap_or("sharegpt");
                let dataset = Dataset::by_name(ds_name).with_context(|| {
                    format!("{src}:1: classes[{k}]: unknown dataset '{ds_name}'")
                })?;
                out.push(ReplayClass { name: leak(name), dataset });
            }
            Some(out)
        }
        None => None,
    };
    let lineage = match j.get("source") {
        Some(v) => Some(
            v.as_str()
                .with_context(|| format!("{src}:1: 'source' must be a string"))?
                .to_string(),
        ),
        None => None,
    };
    Ok(Header { duration, warmup, classes, lineage })
}

/// A record field that must be a non-negative integer.
fn usize_field(j: &Json, key: &str, src: &str, line: usize) -> Result<usize> {
    let x = j
        .get(key)
        .and_then(|v| v.as_f64())
        .with_context(|| format!("{src}:{line}: missing or non-numeric '{key}'"))?;
    if !x.is_finite() || x < 0.0 || x.fract() != 0.0 || x > 1e12 {
        bail!("{src}:{line}: '{key}' must be a non-negative integer, got {x}");
    }
    Ok(x as usize)
}

impl ReplayTrace {
    /// Parse log text with a source label used in error messages and
    /// reports.
    pub fn parse_named(text: &str, src: &str) -> Result<ReplayTrace> {
        let mut records: Vec<ReplayRecord> = Vec::new();
        let mut header: Option<Header> = None;
        for (idx, raw) in text.lines().enumerate() {
            let n = idx + 1; // 1-based, as editors number lines
            let line = raw.trim();
            if line.is_empty() {
                bail!("{src}:{n}: blank line (recorded logs carry one JSON record per line)");
            }
            let j = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("{src}:{n}: malformed record: {e}"))?;
            if !matches!(j, Json::Obj(_)) {
                bail!("{src}:{n}: expected a JSON object, got '{line}'");
            }
            if n == 1 && j.get("ecoserve_trace").is_some() {
                header = Some(parse_header(&j, src)?);
                continue;
            }
            let arrival = j
                .get("arrival_s")
                .and_then(|v| v.as_f64())
                .with_context(|| format!("{src}:{n}: missing or non-numeric 'arrival_s'"))?;
            if !arrival.is_finite() || arrival < 0.0 {
                bail!("{src}:{n}: 'arrival_s' must be non-negative and finite, got {arrival}");
            }
            if let Some(d) = header.as_ref().and_then(|h| h.duration) {
                if arrival > d {
                    bail!(
                        "{src}:{n}: arrival {arrival} lies beyond the declared \
                         duration_s {d}"
                    );
                }
            }
            let input_len = usize_field(&j, "input_len", src, n)?;
            let output_len = usize_field(&j, "output_len", src, n)?;
            if input_len == 0 || output_len == 0 {
                bail!("{src}:{n}: zero-token request (input {input_len}, output {output_len})");
            }
            let class = match j.get("class") {
                Some(_) => usize_field(&j, "class", src, n)?,
                None => 0,
            };
            match header.as_ref().and_then(|h| h.classes.as_ref()) {
                Some(cs) => {
                    if class >= cs.len() {
                        bail!(
                            "{src}:{n}: class {class} out of range (header declares {} classes)",
                            cs.len()
                        );
                    }
                }
                None => {
                    if class >= MAX_INFERRED_CLASSES {
                        bail!(
                            "{src}:{n}: class {class} exceeds the headerless cap of \
                             {MAX_INFERRED_CLASSES} — declare a 'classes' table in the header"
                        );
                    }
                }
            }
            records.push(ReplayRecord { arrival, input_len, output_len, class });
        }
        if records.is_empty() {
            bail!("{src}: empty log — no records to replay");
        }

        // Re-sort out-of-order logs with build_trace's tie-break: arrival,
        // then original order (a stable sort keeps equal arrivals in line
        // order, exactly as merged synthetic streams order ties by id).
        records.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap_or(std::cmp::Ordering::Equal)
        });

        let header = header
            .unwrap_or(Header { duration: None, warmup: None, classes: None, lineage: None });
        let last_arrival = records.last().map(|r| r.arrival).unwrap_or(0.0);
        let duration = header.duration.unwrap_or(last_arrival);
        if duration <= 0.0 {
            bail!(
                "{src}: log spans zero seconds — declare a positive 'duration_s' \
                 in the header"
            );
        }
        let warmup = header.warmup.unwrap_or_else(|| (duration / 8.0).min(30.0));
        if warmup >= duration {
            bail!("{src}: warmup_s {warmup} must be smaller than the {duration}s horizon");
        }
        let classes = match header.classes {
            Some(cs) => cs,
            None => {
                let n = records.iter().map(|r| r.class).max().unwrap_or(0) + 1;
                (0..n)
                    .map(|k| ReplayClass {
                        name: leak(format!("class-{k}")),
                        dataset: Dataset::sharegpt(),
                    })
                    .collect()
            }
        };
        Ok(ReplayTrace {
            records,
            classes,
            duration,
            warmup,
            source: src.to_string(),
            lineage: header.lineage,
        })
    }

    /// Build a trace directly from parsed parts — the import adapters'
    /// materialized path ([`crate::workload::import`]). Invariants mirror
    /// [`ReplayTrace::parse_named`]: non-empty records and classes, class
    /// indices in range, positive finite duration, warmup below it, and
    /// records stable-sorted by arrival (a pre-sorted input is left
    /// untouched, preserving the caller's tie-break order bit-for-bit).
    pub fn from_parts(
        mut records: Vec<ReplayRecord>,
        classes: Vec<ReplayClass>,
        duration: f64,
        warmup: f64,
        source: String,
        lineage: Option<String>,
    ) -> Result<ReplayTrace> {
        if records.is_empty() {
            bail!("{source}: empty trace — no records to replay");
        }
        if classes.is_empty() {
            bail!("{source}: class table must not be empty");
        }
        if !duration.is_finite() || duration <= 0.0 {
            bail!("{source}: duration must be positive and finite, got {duration}");
        }
        if !warmup.is_finite() || warmup < 0.0 || warmup >= duration {
            bail!("{source}: warmup {warmup} must sit inside the {duration}s horizon");
        }
        for r in &records {
            if r.class >= classes.len() {
                bail!(
                    "{source}: class {} out of range ({} classes declared)",
                    r.class,
                    classes.len()
                );
            }
            if !r.arrival.is_finite() || r.arrival < 0.0 || r.arrival > duration {
                bail!("{source}: arrival {} outside [0, {duration}]", r.arrival);
            }
            if r.input_len == 0 || r.output_len == 0 {
                bail!("{source}: zero-token request at arrival {}", r.arrival);
            }
        }
        if !records.windows(2).all(|w| w[0].arrival <= w[1].arrival) {
            records.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        }
        Ok(ReplayTrace { records, classes, duration, warmup, source, lineage })
    }

    /// Parse log text (source label "inline").
    pub fn parse(text: &str) -> Result<ReplayTrace> {
        Self::parse_named(text, "inline")
    }

    /// Read and parse a log file; errors carry the file name.
    pub fn from_file(path: &Path) -> Result<ReplayTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read arrival log {}", path.display()))?;
        let label = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        Self::parse_named(&text, &label)
    }

    // ---- accessors ------------------------------------------------------

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records in replay order (sorted by arrival, ties by line order).
    pub fn records(&self) -> &[ReplayRecord] {
        &self.records
    }

    pub fn classes(&self) -> &[ReplayClass] {
        &self.classes
    }

    /// Recorded span, seconds.
    pub fn duration(&self) -> f64 {
        self.duration
    }

    /// Scoring warm-up prefix, seconds (native time).
    pub fn warmup(&self) -> f64 {
        self.warmup
    }

    pub fn source(&self) -> &str {
        &self.source
    }

    /// Full provenance from the log header's `source` field, when the
    /// header declared one (recorded logs stamp scenario/seed/rate here;
    /// imported traces stamp the upstream format and file).
    pub fn lineage(&self) -> Option<&str> {
        self.lineage.as_deref()
    }

    /// Time-averaged offered rate of the recorded log, req/s.
    pub fn native_rate(&self) -> f64 {
        self.records.len() as f64 / self.duration
    }

    /// The log-assigned class of replayed request `id` (ids are the
    /// replay-order index — see [`ReplayTrace::requests_at`]). This is
    /// the side table behind `Scenario::class_of` for replay scenarios:
    /// log classes are arbitrary per request, so the synthetic id-tag
    /// modulo arithmetic would misattribute them.
    pub fn class_of(&self, id: u64) -> usize {
        self.records[id as usize].class
    }

    /// Requests per class, whole log.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.classes.len().max(1)];
        for r in &self.records {
            counts[r.class] += 1;
        }
        counts
    }

    /// Time-warped replay at time-averaged `rate` req/s: every arrival is
    /// scaled by `native_rate / rate` (lengths untouched), then clipped
    /// to `horizon` seconds. Request ids are the replay-order index, the
    /// key [`ReplayTrace::class_of`] resolves. At `rate == native_rate`
    /// the warp is exactly 1.0 — arrivals are bit-for-bit the recorded
    /// values.
    pub fn requests_at(&self, rate: f64, horizon: f64) -> Vec<Request> {
        // A zero/negative/NaN rate (CLI typo) degrades to an extreme
        // stretch whose arrivals all fall past the horizon — an empty
        // window, like the synthetic shapes' MIN_RATE clamp — instead of
        // panicking. Any real rate is far above the floor, so the warp
        // (and the bit-for-bit native replay) is unaffected.
        let warp = self.native_rate() / rate.max(1e-9);
        let mut out = Vec::with_capacity(self.records.len());
        for (i, rec) in self.records.iter().enumerate() {
            let arrival = rec.arrival * warp;
            if arrival > horizon {
                break; // sorted: every later record is beyond the horizon too
            }
            out.push(Request {
                id: i as u64,
                arrival,
                input_len: rec.input_len,
                output_len: rec.output_len,
            });
        }
        out
    }

    /// Tile the log end-to-end `repeats` times: copy `k` replays the
    /// recorded arrivals shifted by `k · duration`, with lengths and
    /// class assignments untouched, so a short capture drives an
    /// arbitrarily long horizon while preserving the recorded burst
    /// structure (`--loop`). The native rate is preserved (`repeats·n`
    /// requests over `repeats·duration` seconds); the warm-up prefix
    /// stays the original one — later tiles are steady state by
    /// construction. `repeats == 1` is the identity; requests are
    /// clamped at [`MAX_TILE_REPEATS`] copies so a typo'd horizon (or a
    /// saturated float cast) caps the allocation instead of exhausting
    /// memory.
    pub fn tiled(&self, repeats: usize) -> ReplayTrace {
        let repeats = repeats.clamp(1, MAX_TILE_REPEATS);
        if repeats == 1 {
            return self.clone();
        }
        let total = repeats as f64 * self.duration;
        let mut records = Vec::with_capacity(self.records.len() * repeats);
        for k in 0..repeats {
            let shift = k as f64 * self.duration;
            for rec in &self.records {
                // The clamp only ever acts on a record sitting exactly on
                // the recorded horizon whose shifted sum rounds an ulp past
                // `total` — everything else round-trips bit-for-bit.
                let arrival = (rec.arrival + shift).min(total);
                records.push(ReplayRecord { arrival, ..rec.clone() });
            }
        }
        ReplayTrace {
            records,
            classes: self.classes.clone(),
            duration: total,
            warmup: self.warmup,
            source: format!("{} x{repeats}", self.source),
            lineage: self.lineage.clone(),
        }
    }

    /// [`ReplayTrace::tiled`] to at least `horizon` seconds: the smallest
    /// whole number of copies whose span covers it. Non-finite or
    /// not-longer horizons are the identity (the CLI rejects them before
    /// this); the copy count is capped at [`MAX_TILE_REPEATS`].
    pub fn loop_to(&self, horizon: f64) -> ReplayTrace {
        if !horizon.is_finite() || !(horizon > self.duration) {
            return self.clone();
        }
        self.tiled((horizon / self.duration).ceil() as usize)
    }

    /// Serialize back to the wire format (header + one record per line).
    /// The header's `source` field carries the full lineage when one was
    /// parsed, so round-trips through the wire format never lose
    /// provenance.
    pub fn render(&self) -> String {
        render_log(
            &self.classes,
            self.duration,
            self.warmup,
            self.lineage.as_deref().unwrap_or(&self.source),
            self.records.iter().cloned(),
        )
    }
}

/// Serialize a trace to the recorded-log JSONL format: a header line
/// followed by one record per line, through [`crate::util::json`] so
/// numbers round-trip bit-for-bit (shortest-representation floats).
pub fn render_log(
    classes: &[ReplayClass],
    duration: f64,
    warmup: f64,
    source: &str,
    records: impl Iterator<Item = ReplayRecord>,
) -> String {
    let header = Json::obj(vec![
        ("ecoserve_trace", Json::num(FORMAT_VERSION)),
        ("duration_s", Json::num(duration)),
        ("warmup_s", Json::num(warmup)),
        ("source", Json::str(source)),
        (
            "classes",
            Json::arr(classes.iter().map(|c| {
                Json::obj(vec![
                    ("name", Json::str(c.name)),
                    ("dataset", Json::str(c.dataset.name)),
                ])
            })),
        ),
    ]);
    let mut out = header.to_string();
    out.push('\n');
    for rec in records {
        let line = Json::obj(vec![
            ("arrival_s", Json::num(rec.arrival)),
            ("input_len", Json::num(rec.input_len as f64)),
            ("output_len", Json::num(rec.output_len as f64)),
            ("class", Json::num(rec.class as f64)),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(arrival: f64, input: usize, output: usize) -> String {
        format!(
            "{{\"arrival_s\":{arrival},\"input_len\":{input},\"output_len\":{output}}}"
        )
    }

    #[test]
    fn parses_headerless_log_and_infers_shape() {
        let text = [line(1.0, 10, 5), line(2.0, 20, 5), line(3.0, 30, 5), line(4.0, 40, 5)]
            .join("\n");
        let t = ReplayTrace::parse(&text).unwrap();
        assert_eq!(t.len(), 4);
        assert_eq!(t.duration(), 4.0);
        assert_eq!(t.native_rate(), 1.0);
        assert_eq!(t.classes().len(), 1);
        assert_eq!(t.classes()[0].dataset.name, "ShareGPT");
        assert!(t.warmup() > 0.0 && t.warmup() < t.duration());
        assert_eq!(t.class_counts(), vec![4]);
    }

    #[test]
    fn out_of_order_arrivals_resort_with_stable_tie_break() {
        // Line order: 2.0, 1.0, 1.0 — the two ties must keep line order
        // after the sort (the build_trace tie-break).
        let text = [line(2.0, 111, 5), line(1.0, 222, 5), line(1.0, 333, 5)].join("\n");
        let t = ReplayTrace::parse(&text).unwrap();
        let inputs: Vec<usize> = t.records().iter().map(|r| r.input_len).collect();
        assert_eq!(inputs, vec![222, 333, 111]);
        let reqs = t.requests_at(t.native_rate(), t.duration());
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[0].id, 0);
        assert_eq!(reqs[0].input_len, 222);
        assert_eq!(reqs[2].input_len, 111);
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival && w[0].id < w[1].id);
        }
    }

    #[test]
    fn malformed_and_blank_lines_error_with_line_numbers() {
        let bad_json = format!("{}\n{{not json\n{}", line(1.0, 1, 1), line(2.0, 1, 1));
        let err = format!("{:#}", ReplayTrace::parse_named(&bad_json, "log").unwrap_err());
        assert!(err.contains("log:2"), "{err}");

        let blank = format!("{}\n\n{}", line(1.0, 1, 1), line(2.0, 1, 1));
        let err = format!("{:#}", ReplayTrace::parse_named(&blank, "log").unwrap_err());
        assert!(err.contains("log:2") && err.contains("blank"), "{err}");

        let missing = "{\"arrival_s\":1.0,\"input_len\":7}";
        let err = format!("{:#}", ReplayTrace::parse_named(missing, "log").unwrap_err());
        assert!(err.contains("log:1") && err.contains("output_len"), "{err}");

        let zero_len = "{\"arrival_s\":1.0,\"input_len\":0,\"output_len\":5}";
        let err = format!("{:#}", ReplayTrace::parse(zero_len).unwrap_err());
        assert!(err.contains("zero-token"), "{err}");

        let bad_arrival = "{\"arrival_s\":-2.0,\"input_len\":3,\"output_len\":5}";
        let err = format!("{:#}", ReplayTrace::parse(bad_arrival).unwrap_err());
        assert!(err.contains("arrival_s"), "{err}");

        // Headerless class indices are capped: one corrupt record must be
        // a parse error, not a max_class+1-sized allocation.
        let huge = "{\"arrival_s\":1.0,\"input_len\":3,\"output_len\":5,\"class\":999999999}";
        let err = format!("{:#}", ReplayTrace::parse_named(huge, "log").unwrap_err());
        assert!(err.contains("log:1") && err.contains("headerless cap"), "{err}");
    }

    #[test]
    fn empty_logs_are_rejected() {
        let err = format!("{:#}", ReplayTrace::parse("").unwrap_err());
        assert!(err.contains("empty log"), "{err}");
        // A header with no records is still empty.
        let header_only = "{\"ecoserve_trace\":1,\"duration_s\":10}";
        let err = format!("{:#}", ReplayTrace::parse(header_only).unwrap_err());
        assert!(err.contains("empty log"), "{err}");
    }

    #[test]
    fn header_declares_classes_horizon_and_bounds() {
        let text = "{\"ecoserve_trace\":1,\"duration_s\":10,\"warmup_s\":2,\"classes\":\
                    [{\"name\":\"chat\",\"dataset\":\"sharegpt\"},\
                     {\"name\":\"batch\",\"dataset\":\"longbench\"}]}\n\
                    {\"arrival_s\":0.5,\"input_len\":100,\"output_len\":50,\"class\":0}\n\
                    {\"arrival_s\":1.5,\"input_len\":2000,\"output_len\":20,\"class\":1}\n";
        let t = ReplayTrace::parse(text).unwrap();
        assert_eq!(t.duration(), 10.0);
        assert_eq!(t.warmup(), 2.0);
        assert_eq!(t.classes().len(), 2);
        assert_eq!(t.classes()[0].name, "chat");
        assert_eq!(t.classes()[1].dataset.name, "LongBench");
        assert_eq!(t.class_of(0), 0);
        assert_eq!(t.class_of(1), 1);
        assert_eq!(t.class_counts(), vec![1, 1]);
        assert!((t.native_rate() - 0.2).abs() < 1e-12);

        // Class index beyond the declared table.
        let bad = text.replace("\"class\":1}", "\"class\":2}");
        let err = format!("{:#}", ReplayTrace::parse_named(&bad, "log").unwrap_err());
        assert!(err.contains("log:3") && err.contains("out of range"), "{err}");

        // Arrival beyond the declared horizon.
        let bad = text.replace("\"arrival_s\":1.5", "\"arrival_s\":11.5");
        let err = format!("{:#}", ReplayTrace::parse_named(&bad, "log").unwrap_err());
        assert!(err.contains("log:3") && err.contains("beyond"), "{err}");

        // Unknown dataset name in the class table.
        let bad = text.replace("longbench", "imagenet");
        let err = format!("{:#}", ReplayTrace::parse_named(&bad, "log").unwrap_err());
        assert!(err.contains("unknown dataset"), "{err}");
    }

    #[test]
    fn time_warp_rescales_arrivals_and_preserves_lengths() {
        let text = [line(1.0, 10, 5), line(2.0, 20, 6), line(3.0, 30, 7), line(4.0, 40, 8)]
            .join("\n");
        let t = ReplayTrace::parse(&text).unwrap(); // native 1 req/s over 4s

        // Compress 2x: arrivals halve, lengths untouched, all fit.
        let fast = t.requests_at(2.0, t.duration());
        assert_eq!(fast.len(), 4);
        let arrivals: Vec<f64> = fast.iter().map(|r| r.arrival).collect();
        assert_eq!(arrivals, vec![0.5, 1.0, 1.5, 2.0]);
        assert_eq!(fast[2].input_len, 30);
        assert_eq!(fast[3].output_len, 8);

        // Stretch 2x with the native horizon: the tail is clipped and the
        // offered rate over the window is the probe rate.
        let slow = t.requests_at(0.5, t.duration());
        assert_eq!(slow.len(), 2);
        assert_eq!(slow[0].arrival, 2.0);
        assert_eq!(slow[1].arrival, 4.0);

        // Native rate: bit-for-bit the recorded arrivals.
        let native = t.requests_at(t.native_rate(), t.duration());
        for (req, rec) in native.iter().zip(t.records()) {
            assert_eq!(req.arrival.to_bits(), rec.arrival.to_bits());
        }
    }

    #[test]
    fn tiling_shifts_copies_and_preserves_rate_and_classes() {
        let text = "{\"ecoserve_trace\":1,\"duration_s\":10,\"warmup_s\":2,\"classes\":\
                    [{\"name\":\"chat\",\"dataset\":\"sharegpt\"},\
                     {\"name\":\"batch\",\"dataset\":\"longbench\"}]}\n\
                    {\"arrival_s\":1.5,\"input_len\":100,\"output_len\":50,\"class\":0}\n\
                    {\"arrival_s\":7.25,\"input_len\":2000,\"output_len\":20,\"class\":1}\n";
        let t = ReplayTrace::parse_named(text, "unit").unwrap();
        let t3 = t.tiled(3);
        assert_eq!(t3.len(), 6);
        assert_eq!(t3.duration(), 30.0);
        assert_eq!(t3.warmup(), t.warmup());
        assert_eq!(t3.class_counts(), vec![3, 3]);
        assert!((t3.native_rate() - t.native_rate()).abs() < 1e-12);
        let arrivals: Vec<f64> = t3.records().iter().map(|r| r.arrival).collect();
        assert_eq!(arrivals, vec![1.5, 7.25, 11.5, 17.25, 21.5, 27.25]);
        // Copies carry the same lengths and log-assigned classes.
        assert_eq!(t3.records()[2].input_len, 100);
        assert_eq!(t3.records()[3].class, 1);
        assert_eq!(t3.class_of(4), 0);
        assert_eq!(t3.source(), "unit x3");
        // tiled(1) and a loop inside the recorded span are the identity.
        assert_eq!(t.tiled(1).records(), t.records());
        assert_eq!(t.loop_to(5.0).records(), t.records());
        assert_eq!(t.loop_to(10.0).duration(), 10.0);
        // loop_to rounds up to whole copies.
        assert_eq!(t.loop_to(25.0).duration(), 30.0);
        assert_eq!(t.loop_to(25.0).len(), 6);
        // Absurd horizons cap at MAX_TILE_REPEATS instead of allocating
        // unboundedly (a saturated float cast lands on usize::MAX).
        assert_eq!(t.tiled(usize::MAX).len(), 2 * MAX_TILE_REPEATS);
        assert_eq!(t.loop_to(1e300).len(), 2 * MAX_TILE_REPEATS);
        // Non-finite horizons are the identity (the CLI rejects them).
        assert_eq!(t.loop_to(f64::INFINITY).records(), t.records());
        assert_eq!(t.loop_to(f64::NAN).records(), t.records());
    }

    #[test]
    fn tiled_log_renders_and_parses_round_trip() {
        let text = "{\"ecoserve_trace\":1,\"duration_s\":8,\"warmup_s\":1}\n\
                    {\"arrival_s\":0.3333333333333333,\"input_len\":10,\"output_len\":5}\n\
                    {\"arrival_s\":6.1,\"input_len\":20,\"output_len\":7}\n";
        let tiled = ReplayTrace::parse_named(text, "unit").unwrap().tiled(4);
        let back = ReplayTrace::parse_named(&tiled.render(), "unit x4").unwrap();
        assert_eq!(back.records(), tiled.records());
        assert_eq!(back.duration(), tiled.duration());
        assert_eq!(back.warmup(), tiled.warmup());
        for (a, b) in back.records().iter().zip(tiled.records()) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        }
    }

    /// The header `source` field is lineage, not the display label: a log
    /// loaded under a file-name label keeps reporting that label while the
    /// full provenance survives every render → parse round-trip.
    #[test]
    fn header_source_is_lineage_and_survives_round_trips() {
        let text = "{\"ecoserve_trace\":1,\"duration_s\":10,\
                    \"source\":\"scenario 'bursty' seed 7 @ 6 req/s\"}\n\
                    {\"arrival_s\":0.5,\"input_len\":100,\"output_len\":50}\n";
        let t = ReplayTrace::parse_named(text, "trace.jsonl").unwrap();
        assert_eq!(t.source(), "trace.jsonl");
        assert_eq!(t.lineage(), Some("scenario 'bursty' seed 7 @ 6 req/s"));
        // Render under a different label: the lineage wins in the header.
        let back = ReplayTrace::parse_named(&t.render(), "copy.jsonl").unwrap();
        assert_eq!(back.source(), "copy.jsonl");
        assert_eq!(back.lineage(), t.lineage());
        // Tiling keeps the lineage too.
        assert_eq!(t.tiled(3).lineage(), t.lineage());
        // Headerless logs have no lineage; render stamps the label.
        let bare = ReplayTrace::parse_named(
            "{\"arrival_s\":1.0,\"input_len\":2,\"output_len\":3}",
            "bare.jsonl",
        )
        .unwrap();
        assert_eq!(bare.lineage(), None);
        assert!(bare.render().contains("\"source\":\"bare.jsonl\""));
    }

    #[test]
    fn from_parts_validates_and_preserves_order() {
        let classes = vec![ReplayClass { name: "chat", dataset: Dataset::sharegpt() }];
        let recs = vec![
            ReplayRecord { arrival: 0.25, input_len: 10, output_len: 5, class: 0 },
            ReplayRecord { arrival: 0.25, input_len: 20, output_len: 5, class: 0 },
            ReplayRecord { arrival: 1.5, input_len: 30, output_len: 5, class: 0 },
        ];
        let t = ReplayTrace::from_parts(
            recs.clone(),
            classes.clone(),
            4.0,
            0.5,
            "parts".into(),
            Some("upstream.csv".into()),
        )
        .unwrap();
        // Pre-sorted ties keep their order (no re-sort churn).
        assert_eq!(t.records(), &recs[..]);
        assert_eq!(t.lineage(), Some("upstream.csv"));
        assert_eq!(t.source(), "parts");
        // Invariant violations are loud.
        let e = |r| {
            format!(
                "{:#}",
                ReplayTrace::from_parts(r, classes.clone(), 4.0, 0.5, "p".into(), None)
                    .unwrap_err()
            )
        };
        assert!(e(vec![]).contains("empty"));
        let bad_class =
            vec![ReplayRecord { arrival: 0.1, input_len: 1, output_len: 1, class: 7 }];
        assert!(e(bad_class).contains("out of range"));
        let zero = vec![ReplayRecord { arrival: 0.1, input_len: 0, output_len: 1, class: 0 }];
        assert!(e(zero).contains("zero-token"));
        let late = vec![ReplayRecord { arrival: 9.0, input_len: 1, output_len: 1, class: 0 }];
        assert!(e(late).contains("outside"));
    }

    #[test]
    fn render_parse_round_trip_is_bit_for_bit() {
        // Awkward floats on purpose: shortest-representation serialization
        // must reproduce them exactly.
        let records = vec![
            ReplayRecord {
                arrival: 0.023217066548171496,
                input_len: 61,
                output_len: 1027,
                class: 0,
            },
            ReplayRecord { arrival: 1.0 / 3.0, input_len: 54, output_len: 45, class: 1 },
            ReplayRecord { arrival: 2.0, input_len: 642, output_len: 2048, class: 0 },
        ];
        let classes = vec![
            ReplayClass { name: "chat", dataset: Dataset::sharegpt() },
            ReplayClass { name: "batch", dataset: Dataset::longbench() },
        ];
        let text = render_log(&classes, 10.0, 1.5, "unit", records.iter().cloned());
        let t = ReplayTrace::parse_named(&text, "unit").unwrap();
        assert_eq!(t.records(), &records[..]);
        assert_eq!(t.duration(), 10.0);
        assert_eq!(t.warmup(), 1.5);
        assert_eq!(t.classes()[1].name, "batch");
        // And rendering the parsed trace reproduces the text verbatim.
        assert_eq!(t.render(), text);
    }
}
