//! Arrival processes: Poisson traces at a fixed rate (the paper's
//! end-to-end evaluation setting — "a Poisson distribution is applied to a
//! fixed request rate") and piecewise ramps (Figure 10's dynamic-scaling
//! experiment, 20 → 50 req/s in 2-minute steps).

use super::datasets::Dataset;
use super::Request;
use crate::util::rng::Pcg64;

/// Generates request traces from a dataset's length models.
#[derive(Debug, Clone)]
pub struct TraceGenerator {
    pub dataset: Dataset,
    pub seed: u64,
}

impl TraceGenerator {
    pub fn new(dataset: Dataset, seed: u64) -> Self {
        TraceGenerator { dataset, seed }
    }

    /// Poisson arrivals at `rate` req/s over `duration` seconds.
    pub fn poisson(&self, rate: f64, duration: f64) -> Vec<Request> {
        assert!(rate > 0.0 && duration > 0.0);
        let mut rng = Pcg64::new(self.seed, 0xA11);
        let mut out = Vec::with_capacity((rate * duration * 1.2) as usize + 8);
        let mut t = 0.0;
        let mut id = 0;
        loop {
            t += rng.exponential(rate);
            if t >= duration {
                break;
            }
            out.push(Request {
                id,
                arrival: t,
                input_len: self.dataset.input.sample(&mut rng),
                output_len: self.dataset.output.sample(&mut rng),
            });
            id += 1;
        }
        out
    }

    /// Piecewise-constant-rate Poisson trace: `steps` of (rate, duration).
    pub fn ramp(&self, steps: &[(f64, f64)]) -> Vec<Request> {
        let mut rng = Pcg64::new(self.seed, 0xA12);
        let mut out = Vec::new();
        let mut base = 0.0;
        let mut id = 0;
        for &(rate, dur) in steps {
            let mut t = 0.0;
            loop {
                t += rng.exponential(rate);
                if t >= dur {
                    break;
                }
                out.push(Request {
                    id,
                    arrival: base + t,
                    input_len: self.dataset.input.sample(&mut rng),
                    output_len: self.dataset.output.sample(&mut rng),
                });
                id += 1;
            }
            base += dur;
        }
        out
    }
}

/// The Figure 10 ramp: request rate increases every `step_secs` from
/// `start_rate` to `end_rate` in `increments` equal steps.
#[derive(Debug, Clone)]
pub struct RampTrace {
    pub start_rate: f64,
    pub end_rate: f64,
    pub increments: usize,
    pub step_secs: f64,
}

impl RampTrace {
    /// The paper's Figure 10 setting: 20 → 50 req/s, steps every 2 minutes.
    pub fn fig10() -> Self {
        RampTrace { start_rate: 20.0, end_rate: 50.0, increments: 6, step_secs: 120.0 }
    }

    pub fn steps(&self) -> Vec<(f64, f64)> {
        let n = self.increments.max(1);
        (0..n)
            .map(|i| {
                let frac = if n == 1 { 0.0 } else { i as f64 / (n - 1) as f64 };
                (
                    self.start_rate + (self.end_rate - self.start_rate) * frac,
                    self.step_secs,
                )
            })
            .collect()
    }

    pub fn total_duration(&self) -> f64 {
        self.increments as f64 * self.step_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_respected() {
        let g = TraceGenerator::new(Dataset::sharegpt(), 42);
        let reqs = g.poisson(10.0, 500.0);
        let rate = reqs.len() as f64 / 500.0;
        assert!((rate - 10.0).abs() < 0.5, "rate={rate}");
        // sorted arrivals, unique ids
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
            assert!(w[0].id < w[1].id);
        }
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        let g = TraceGenerator::new(Dataset::alpaca(), 7);
        let a = g.poisson(5.0, 100.0);
        let b = g.poisson(5.0, 100.0);
        assert_eq!(a, b);
        let g2 = TraceGenerator::new(Dataset::alpaca(), 8);
        assert_ne!(a, g2.poisson(5.0, 100.0));
    }

    #[test]
    fn ramp_steps_cover_range() {
        let r = RampTrace::fig10();
        let steps = r.steps();
        assert_eq!(steps.len(), 6);
        assert_eq!(steps[0].0, 20.0);
        assert_eq!(steps[5].0, 50.0);
        assert_eq!(r.total_duration(), 720.0);
    }

    /// The PRNG beneath every arrival process is pinned bit-for-bit:
    /// these u64 outputs are platform-independent integer arithmetic
    /// (goldens computed from an independent PCG64 implementation), so a
    /// silent change to `util::rng` — which would invalidate every
    /// recorded experiment — fails here first.
    #[test]
    fn arrival_prng_is_pinned_bit_for_bit() {
        let mut g = Pcg64::seeded(42);
        assert_eq!(
            [g.next_u64(), g.next_u64(), g.next_u64(), g.next_u64()],
            [
                4540806433264105130,
                7249376888367367666,
                1981322806045522308,
                9441508507294158916,
            ]
        );
        // The exact stream the Poisson generator forks for seed 7.
        let mut p = Pcg64::new(7, 0xA11);
        assert_eq!(p.next_u64(), 3821966030618647287);
        assert_eq!(p.next_u64(), 14877528384138739846);
    }

    /// Golden first-arrivals for the Poisson process (seed 7, ShareGPT,
    /// 10 req/s). Arrival times are pinned to 1e-9 relative (libm `ln`
    /// may differ by an ulp across platforms); sampled lengths are exact.
    #[test]
    fn poisson_matches_golden_trace() {
        let g = TraceGenerator::new(Dataset::sharegpt(), 7);
        let reqs = g.poisson(10.0, 100.0);
        let golden = [
            (0.023217066548171496, 61usize, 1027usize),
            (0.02627262761252519, 54, 45),
            (0.08672561249800251, 642, 2048),
        ];
        for (i, (t, inp, out)) in golden.into_iter().enumerate() {
            let r = &reqs[i];
            assert!(
                (r.arrival - t).abs() <= 1e-9 * t.max(1.0),
                "req {i} arrival {} vs golden {t}",
                r.arrival
            );
            assert_eq!(r.input_len, inp, "req {i} input");
            assert_eq!(r.output_len, out, "req {i} output");
        }
    }

    /// Ramp traces are bit-for-bit deterministic per seed: two generators
    /// built independently from the same (dataset, seed) must emit equal
    /// traces — the same contract `sim::engine` gives events — and the
    /// first arrivals match goldens from the independent implementation.
    #[test]
    fn ramp_deterministic_per_seed_bit_for_bit() {
        let steps = [(5.0, 40.0), (15.0, 40.0)];
        let a = TraceGenerator::new(Dataset::sharegpt(), 7).ramp(&steps);
        let b = TraceGenerator::new(Dataset::sharegpt(), 7).ramp(&steps);
        assert_eq!(a, b, "same seed, same ramp, different traces");
        assert_ne!(a, TraceGenerator::new(Dataset::sharegpt(), 8).ramp(&steps));

        // Golden anchor (seed 7): ~812 arrivals, first three pinned.
        assert!(
            (810..=814).contains(&a.len()),
            "ramp length {} drifted from golden 812",
            a.len()
        );
        let golden = [
            (0.6310978863584902, 156usize, 76usize),
            (0.6331215153050598, 602, 246),
            (0.6619256835496219, 318, 65),
        ];
        for (i, (t, inp, out)) in golden.into_iter().enumerate() {
            assert!(
                (a[i].arrival - t).abs() <= 1e-9 * t.max(1.0),
                "req {i} arrival {} vs golden {t}",
                a[i].arrival
            );
            assert_eq!(a[i].input_len, inp);
            assert_eq!(a[i].output_len, out);
        }
        // Rate split across the two legs (5 vs 15 req/s over 40 s each).
        let early = a.iter().filter(|r| r.arrival < 40.0).count();
        let late = a.len() - early;
        assert!((150..=230).contains(&early), "early {early}");
        assert!(late > 2 * early, "late {late} vs early {early}");
    }

    #[test]
    fn ramp_trace_rates_increase() {
        let g = TraceGenerator::new(Dataset::sharegpt(), 3);
        let r = RampTrace { start_rate: 2.0, end_rate: 20.0, increments: 3, step_secs: 100.0 };
        let reqs = g.ramp(&r.steps());
        let early = reqs.iter().filter(|q| q.arrival < 100.0).count();
        let late = reqs.iter().filter(|q| q.arrival >= 200.0).count();
        assert!(late > 5 * early, "early={early} late={late}");
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }
}
