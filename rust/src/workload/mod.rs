//! Workload generation: synthetic equivalents of the paper's three
//! evaluation datasets plus Poisson / ramp arrival processes.
//!
//! The paper's schedulers observe only (arrival time, input length, output
//! length); Table 4's per-dataset moments pin the length distributions, so
//! a fitted generator preserves scheduling behaviour (DESIGN.md §2).

pub mod client;
pub mod datasets;
pub mod import;
pub mod replay;
pub mod trace;

pub use client::{ClientLoop, ClientPolicy, ClientTelemetry, RETRY_ID_BASE};
pub use datasets::{Dataset, LengthModel};
pub use import::{StreamedArrivals, StreamedTrace, TraceFormat};
pub use replay::{render_log, ReplayClass, ReplayRecord, ReplayTrace};
pub use trace::{RampTrace, TraceGenerator};

/// One inference request as the cluster sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time at the overall scheduler, seconds.
    pub arrival: f64,
    /// Prompt length, tokens.
    pub input_len: usize,
    /// Generation length, tokens. The *oracle* value: schedulers must not
    /// read it for admission decisions (output length is unknown until EoS,
    /// paper §2.1); the simulator uses it to know when decoding finishes.
    pub output_len: usize,
}

impl Request {
    /// Total KV-cache tokens this request will occupy at completion.
    pub fn total_tokens(&self) -> usize {
        self.input_len + self.output_len
    }
}
