//! EcoServe launcher.
//!
//! Subcommands are declared once in [`ecoserve::util::cli::COMMANDS`] —
//! the dispatch table below, flag validation, and the per-subcommand
//! `--help` text are all generated from that registry. Run
//! `ecoserve <command> --help` for a command's flags.
//!
//! Examples:
//!   ecoserve serve --instances 2 --rate 3 --duration 20
//!   ecoserve simulate --system ecoserve --model codellama-34b \
//!       --cluster l20 --dataset sharegpt --rate 8
//!   ecoserve goodput --system vllm --dataset longbench --level p90
//!   ecoserve scenarios --list
//!   ecoserve scenarios --scenario bursty --out report.json
//!   ecoserve scenarios --scenario steady+churn --fault-seed 7 \
//!       --churn-out BENCH_churn.json
//!   ecoserve scenarios --scenario retry-storm --overload-out BENCH_overload.json
//!   ecoserve frontier --scenario bursty --level p90 --out BENCH_goodput.json
//!   ecoserve frontier --quick --autoscale --gpus 16 --perf-out BENCH_simperf.json
//!   ecoserve record --scenario bursty --rate 6 --out bursty.jsonl
//!   ecoserve scenarios --replay bursty.jsonl
//!   ecoserve frontier --replay bursty.jsonl --quick --autoscale
//!   ecoserve scenarios --replay short.jsonl --loop 600   # tile a short log
//!   ecoserve scenarios --import trace.csv --format burstgpt   # stream an external log
//!   ecoserve frontier --import azure.csv --format azure --quick
//!   ecoserve record --import trace.csv --format azure --out canon.jsonl
//!   ecoserve plan --quick --scenario bursty --model llama-30b --gpus 32
//!   ecoserve plan --quick --spot --scenario steady --gpus 16   # price spot twins
//!   ecoserve plan --scenario steady --target-rate 5 --cluster all \
//!       --out BENCH_plan.json

// Same advisory lint posture as lib.rs (see its comment).
#![allow(clippy::style, clippy::complexity, clippy::perf)]

use anyhow::{bail, Error, Result};

use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use ecoserve::frontier;
use ecoserve::harness;
use ecoserve::metrics::Attainment;
use ecoserve::perfmodel::{self, ModelSpec};
use ecoserve::scenarios;
use ecoserve::util::cli::{self, Args};
use ecoserve::workload::Dataset;

fn main() -> Result<()> {
    let args = Args::from_env();
    let Some(cmd) = args.command() else {
        print_usage();
        return Ok(());
    };
    let Some(spec) = cli::command_spec(cmd) else {
        print_usage();
        bail!("unknown subcommand '{cmd}'");
    };
    if args.has("help") {
        print!("{}", spec.help_text());
        return Ok(());
    }
    // One uniform gate for every subcommand: unknown flags error, and a
    // value-taking flag supplied bare errors before any work starts.
    args.check(spec).map_err(Error::msg)?;
    match cmd {
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "goodput" => cmd_goodput(&args),
        "scenarios" => cmd_scenarios(&args),
        "frontier" => cmd_frontier(&args),
        "plan" => cmd_plan(&args),
        "record" => cmd_record(&args),
        "table2" => cmd_table2(&args),
        "table3" => cmd_table3(),
        _ => unreachable!("command_spec() covers the dispatch table"),
    }
}

/// Top-level usage, generated from the subcommand registry.
fn print_usage() {
    eprintln!("usage: ecoserve <command> [--flags]\n\ncommands:");
    for c in cli::COMMANDS {
        eprintln!("  {:<10} {}", c.name, c.summary);
    }
    eprintln!("\nrun `ecoserve <command> --help` for that command's flags");
}

/// Shared `--model/--cluster/--tp/--pp/--gpus` parsing (simulate,
/// goodput, and scenarios all describe deployments the same way).
fn deployment_from_args(args: &Args) -> Result<Deployment> {
    let model = ModelSpec::by_name(&args.get_or("model", "codellama-34b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let cluster = ClusterSpec::by_name(&args.get_or("cluster", "l20"))
        .ok_or_else(|| anyhow::anyhow!("unknown cluster"))?;
    let mut deployment = Deployment::paper_default(model, cluster);
    if let Some(tp) = args.usize_flag("tp").map_err(Error::msg)? {
        deployment.tp = tp;
    }
    if let Some(pp) = args.usize_flag("pp").map_err(Error::msg)? {
        deployment.pp = pp;
    }
    if let Some(g) = args.usize_flag("gpus").map_err(Error::msg)? {
        deployment.gpus_used = g;
    }
    // Guard every deployment-consuming subcommand here, not per command:
    // downstream constructors (FuDG splits, mitosis N_l clamp) assume at
    // least one instance.
    if deployment.num_instances() == 0 {
        bail!(
            "deployment has zero instances (gpus {} < tp {} x pp {})",
            deployment.gpus_used,
            deployment.tp,
            deployment.pp
        );
    }
    Ok(deployment)
}

fn experiment_from_args(args: &Args) -> Result<ExperimentConfig> {
    let dataset = Dataset::by_name(&args.get_or("dataset", "sharegpt"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let deployment = deployment_from_args(args)?;
    let mut cfg = ExperimentConfig::new(deployment, dataset);
    cfg.seed = args.u64_flag("seed").map_err(Error::msg)?.unwrap_or(42);
    cfg.duration = args.f64_flag("duration").map_err(Error::msg)?.unwrap_or(240.0);
    cfg.warmup = args.f64_flag("warmup").map_err(Error::msg)?.unwrap_or(30.0);
    Ok(cfg)
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> Result<()> {
    use ecoserve::server::{serve_poisson, ServeConfig};
    let mut cfg = ServeConfig::default();
    cfg.instances = args.usize_flag("instances").map_err(Error::msg)?.unwrap_or(2);
    cfg.rate = args.f64_flag("rate").map_err(Error::msg)?.unwrap_or(3.0);
    cfg.duration_secs = args.f64_flag("duration").map_err(Error::msg)?.unwrap_or(20.0);
    cfg.seed = args.u64_flag("seed").map_err(Error::msg)?.unwrap_or(42);
    let artifacts = args.get_or("artifacts", "artifacts");
    let report = serve_poisson(std::path::Path::new(&artifacts), &cfg)?;
    print!("{}", report.render());
    if !report.fatal_errors.is_empty() {
        bail!("worker errors: {:?}", report.fatal_errors);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args) -> Result<()> {
    bail!(
        "the `serve` subcommand needs the live PJRT path: rebuild with \
         `cargo build --release --features pjrt` (and a real `xla` crate — \
         see rust/vendor/xla)"
    )
}

/// Shared `--scenario` / `--replay` / `--import` selection (scenarios +
/// frontier + plan): an external trace streamed through an import
/// adapter, a recorded arrival log (optionally `--loop`-tiled to a
/// longer horizon), one named scenario, or the whole registry.
fn select_scenarios(args: &Args) -> Result<Vec<scenarios::Scenario>> {
    if let Some(path) = args.get_path("import").map_err(Error::msg)? {
        if args.get("scenario").is_some()
            || args.get_path("replay").map_err(Error::msg)?.is_some()
        {
            bail!(
                "--import is mutually exclusive with --scenario/--replay: \
                 the imported trace IS the scenario"
            );
        }
        if args.get("loop").is_some() || args.has_flag("loop") {
            bail!("--loop tiles a recorded --replay log; --import streams the log as-is");
        }
        let format = match args.get("format") {
            Some(name) => ecoserve::workload::TraceFormat::by_name(name)?,
            None => bail!("--import needs --format burstgpt|azure"),
        };
        let window = args
            .f64_flag("window")
            .map_err(Error::msg)?
            .unwrap_or(ecoserve::workload::import::DEFAULT_REORDER_WINDOW_S);
        let stream = ecoserve::workload::StreamedTrace::open(&path, format, window)?;
        let scenario = scenarios::Scenario::from_stream(stream);
        let stream = scenario.stream().expect("from_stream builds a streamed scenario");
        eprintln!(
            "streaming {} ({}): {} requests over {:.0}s ({:.2} req/s native, {} class(es))",
            path.display(),
            stream.format().label(),
            stream.len(),
            stream.duration(),
            stream.native_rate(),
            scenario.classes.len(),
        );
        return Ok(vec![scenario]);
    }
    for flag in ["format", "window"] {
        if args.get(flag).is_some() || args.has_flag(flag) {
            bail!("--{flag} applies to --import <file> (see --help)");
        }
    }
    let replay = args.get_path("replay").map_err(Error::msg)?;
    if let Some(path) = replay {
        if args.get("scenario").is_some() {
            bail!("--replay and --scenario are mutually exclusive: a replay log IS the scenario");
        }
        let mut trace = ecoserve::workload::ReplayTrace::from_file(&path)?;
        if let Some(horizon) = args.f64_flag("loop").map_err(Error::msg)? {
            if !horizon.is_finite() || horizon <= 0.0 {
                bail!("--loop expects a positive finite horizon in seconds, got {horizon}");
            }
            trace = trace.loop_to(horizon);
        }
        let scenario = scenarios::Scenario::from_replay(trace);
        let trace = scenario.replay().expect("from_replay builds a replay scenario");
        eprintln!(
            "replaying {}: {} requests over {:.0}s ({:.2} req/s native, {} class(es))",
            path.display(),
            trace.len(),
            trace.duration(),
            trace.native_rate(),
            scenario.classes.len(),
        );
        return Ok(vec![scenario]);
    }
    if args.get("loop").is_some() || args.has_flag("loop") {
        bail!("--loop tiles a recorded log and needs --replay <log>");
    }
    match args.get("scenario") {
        Some(name) => Ok(vec![scenarios::by_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario '{name}' (try `ecoserve scenarios --list`)")
        })?]),
        None => Ok(scenarios::registry()),
    }
}

/// Export a scenario's deterministic trace in the replay-log format
/// (`record` subcommand): the same JSONL `ecoserve scenarios --replay`
/// and `ecoserve frontier --replay` consume, so any synthetic shape can
/// round-trip through the wire format. `--import`/`--replay` re-record
/// an external or recorded log instead — the exported header keeps the
/// original lineage, so record → import → record chains never lose
/// where the arrivals came from.
fn cmd_record(args: &Args) -> Result<()> {
    let external = args.get_path("import").map_err(Error::msg)?.is_some()
        || args.get_path("replay").map_err(Error::msg)?.is_some();
    let mut scenario = if external {
        // select_scenarios yields exactly one scenario for --import or
        // --replay, and owns the mutual-exclusion/stray-flag errors.
        select_scenarios(args)?.remove(0)
    } else {
        for flag in ["format", "window"] {
            if args.get(flag).is_some() || args.has_flag(flag) {
                bail!("--{flag} applies to --import <file> (see --help)");
            }
        }
        if args.get("loop").is_some() || args.has_flag("loop") {
            bail!("--loop tiles a recorded log and needs --replay <log>");
        }
        let name = args.get_or("scenario", "steady");
        scenarios::by_name(&name).ok_or_else(|| {
            anyhow::anyhow!("unknown scenario '{name}' (try `ecoserve scenarios --list`)")
        })?
    };
    if let Some(d) = args.f64_flag("duration").map_err(Error::msg)? {
        scenario.duration = d;
        scenario.warmup = scenario.warmup.min(d / 4.0);
    }
    let seed = args.u64_flag("seed").map_err(Error::msg)?.unwrap_or(42);
    let rate = args.f64_flag("rate").map_err(Error::msg)?.unwrap_or(scenario.default_rate);
    let log = scenario.record_log(seed, rate);
    let lines = log.lines().count();
    match args.get_path("out").map_err(Error::msg)? {
        Some(path) => {
            std::fs::write(&path, &log)
                .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
            eprintln!(
                "recorded scenario '{}' @ {rate} req/s (seed {seed}) -> {} ({} requests)",
                scenario.name,
                path.display(),
                lines - 1, // minus the header line
            );
        }
        None => print!("{log}"),
    }
    Ok(())
}

/// Shared `--system` selection (scenarios + frontier): one system, or all.
fn select_systems(args: &Args) -> Result<Vec<SystemKind>> {
    match args.get("system") {
        Some(name) => Ok(vec![SystemKind::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown system '{name}'"))?]),
        None => Ok(SystemKind::all().to_vec()),
    }
}

/// The multi-scenario evaluation suite (`scenarios` subcommand).
fn cmd_scenarios(args: &Args) -> Result<()> {
    if args.has_flag("list") {
        println!("{:<20} {:>7} {:>9} {:>8}  summary", "scenario", "rate/s", "horizon", "classes");
        for s in scenarios::registry() {
            println!(
                "{:<20} {:>7.1} {:>8.0}s {:>8}  {}",
                s.name,
                s.default_rate,
                s.duration,
                s.classes.len(),
                s.summary
            );
        }
        return Ok(());
    }

    let selected = select_scenarios(args)?;
    let systems = select_systems(args)?;
    let trace_out = args.get_path("trace-out").map_err(Error::msg)?;

    let cfg = scenarios::ScenarioConfig {
        deployment: deployment_from_args(args)?,
        seed: args.u64_flag("seed").map_err(Error::msg)?.unwrap_or(42),
        rate: args.f64_flag("rate").map_err(Error::msg)?,
        duration_override: args.f64_flag("duration").map_err(Error::msg)?,
        fault_seed: args.u64_flag("fault-seed").map_err(Error::msg)?,
        trace: trace_out.is_some(),
    };

    let d = &cfg.deployment;
    println!(
        "scenario suite: {} scenario(s) x {} system(s) on {} x{} instances (TP={}) / {}",
        selected.len(),
        systems.len(),
        d.model.name,
        d.num_instances(),
        d.tp,
        d.cluster.name,
    );
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);

    // The flight recorder rides the plain suite: the paired churn and
    // overload sweeps run each cell several times, so "the" event log of
    // a cell would be ambiguous there.
    if trace_out.is_some()
        && (args.get("churn-out").is_some() || args.get("overload-out").is_some())
    {
        bail!("--trace-out records the plain suite; drop --churn-out/--overload-out");
    }

    // --churn-out runs the clean-vs-faulted pairing instead of the plain
    // suite: each system runs twice per churn scenario, and the report
    // scores goodput retained under churn.
    if let Some(path) = args.get_path("churn-out").map_err(Error::msg)? {
        let churn: Vec<scenarios::Scenario> =
            selected.iter().filter(|s| s.churn.is_some()).cloned().collect();
        if churn.is_empty() {
            bail!(
                "--churn-out needs a churn scenario (steady+churn, \
                 surge+preemption, spot-decode-reclaim); got only fault-free ones"
            );
        }
        let t0 = std::time::Instant::now();
        let outcomes = scenarios::run_churn_suite(&churn, &cfg, &systems, workers);
        let wall = t0.elapsed();
        for outcome in &outcomes {
            println!();
            print!("{}", scenarios::render_churn_table(outcome));
        }
        let json = scenarios::churn_to_json(&outcomes, &cfg, wall).to_string();
        std::fs::write(&path, &json)
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        println!("\nwrote BENCH churn report to {}", path.display());
        return Ok(());
    }

    // --overload-out runs the undefended-vs-defended load sweep instead:
    // closed-loop clients (timeouts, retries, backoff) push each system
    // past saturation at every load point, once with defenses off and
    // once with them armed, and the report scores the goodput curve.
    if let Some(path) = args.get_path("overload-out").map_err(Error::msg)? {
        let overload: Vec<scenarios::Scenario> =
            selected.iter().filter(|s| s.overload.is_some()).cloned().collect();
        if overload.is_empty() {
            bail!(
                "--overload-out needs an overload scenario (overload-sustained, \
                 retry-storm, slow-drain); got only open-loop ones"
            );
        }
        let t0 = std::time::Instant::now();
        let outcomes = scenarios::run_overload_suite(&overload, &cfg, &systems, workers);
        let wall = t0.elapsed();
        for outcome in &outcomes {
            println!();
            print!("{}", scenarios::render_overload_table(outcome));
        }
        let json = scenarios::overload_to_json(&outcomes, &cfg, wall).to_string();
        std::fs::write(&path, &json)
            .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
        println!("\nwrote BENCH overload report to {}", path.display());
        return Ok(());
    }

    let outcomes = scenarios::run_suite(&selected, &cfg, &systems, workers);
    for outcome in &outcomes {
        println!();
        print!("{}", scenarios::render_table(outcome));
    }

    if let Some(path) = args.get("out") {
        let json = scenarios::suite_to_json(&outcomes, &cfg).to_string();
        std::fs::write(path, &json)
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("\nwrote JSON report to {path}");
    }
    if let Some(path) = &trace_out {
        write_trace_artifacts(&outcomes, &cfg, path)?;
    }
    Ok(())
}

/// Write the flight-recorder artifacts for `--trace-out`: the derived
/// diagnostics (`BENCH_trace.json` schema) at `path`, plus the raw event
/// logs as a Perfetto/Chrome `trace_event` document at the sibling
/// `<stem>.perfetto.json` (open it in https://ui.perfetto.dev).
fn write_trace_artifacts(
    outcomes: &[scenarios::ScenarioOutcome],
    cfg: &scenarios::ScenarioConfig,
    path: &std::path::Path,
) -> Result<()> {
    let json = scenarios::trace_suite_to_json(outcomes, cfg).to_string();
    std::fs::write(path, &json)
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
    println!("wrote BENCH trace report to {}", path.display());

    let tracks: Vec<(String, &[ecoserve::trace::TraceEvent])> = outcomes
        .iter()
        .flat_map(|o| {
            o.rows.iter().filter_map(move |r| {
                r.trace.as_ref().map(|cap| {
                    let label = format!("{} / {}", o.scenario.name, r.system.label());
                    (label, cap.events.as_slice())
                })
            })
        })
        .collect();
    let sibling = perfetto_sibling(path);
    let json = ecoserve::trace::to_perfetto(&tracks).to_string();
    std::fs::write(&sibling, &json)
        .map_err(|e| anyhow::anyhow!("write {}: {e}", sibling.display()))?;
    println!("wrote Perfetto trace to {}", sibling.display());
    Ok(())
}

/// `BENCH_trace.json` -> `BENCH_trace.perfetto.json`; extension-less
/// paths just gain `.perfetto.json`.
fn perfetto_sibling(path: &std::path::Path) -> std::path::PathBuf {
    match path.extension().and_then(|e| e.to_str()) {
        Some(ext) => path.with_extension(format!("perfetto.{ext}")),
        None => path.with_extension("perfetto.json"),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = experiment_from_args(args)?;
    let kind = SystemKind::by_name(&args.get_or("system", "ecoserve"))
        .ok_or_else(|| anyhow::anyhow!("unknown system"))?;
    let rate = args.f64_flag("rate").map_err(Error::msg)?.unwrap_or(4.0);
    let r = harness::run_once(kind, &cfg, rate, None);
    let s = &r.summary;
    println!(
        "{} on {} / {} / {} @ {:.2} req/s",
        kind.label(),
        cfg.deployment.model.name,
        cfg.deployment.cluster.name,
        cfg.dataset.name,
        rate
    );
    println!(
        "  arrived {} completed {} attainment {:.1}%  ({} sim events in {:?})",
        r.arrived,
        s.count,
        r.attainment * 100.0,
        r.events,
        r.wall
    );
    println!(
        "  TTFT p50/p90/p99: {:.2}/{:.2}/{:.2} s   TPOT p50/p90/p99: {:.0}/{:.0}/{:.0} ms",
        s.ttft_p50, s.ttft_p90, s.ttft_p99,
        s.tpot_p50 * 1e3, s.tpot_p90 * 1e3, s.tpot_p99 * 1e3
    );
    println!("  token throughput: {:.0} tok/s", s.token_throughput);
    Ok(())
}

/// Goodput search for one system — a thin wrapper over the frontier
/// search core via [`harness::goodput_search`].
fn cmd_goodput(args: &Args) -> Result<()> {
    let cfg = experiment_from_args(args)?;
    let kind = SystemKind::by_name(&args.get_or("system", "ecoserve"))
        .ok_or_else(|| anyhow::anyhow!("unknown system"))?;
    let level = parse_level(args)?;
    let g = harness::goodput_search(kind, &cfg, level);
    println!(
        "{} {} goodput: {:.2} req/s ({:.0} tok/s) on {}/{}/{}",
        g.system.label(),
        g.level.label(),
        g.rate,
        g.summary.token_throughput,
        cfg.deployment.model.name,
        cfg.deployment.cluster.name,
        cfg.dataset.name,
    );
    if let Some(p) = g.fudg_prefill {
        println!(
            "  (FuDG split: {p} prefill / {} decode)",
            cfg.deployment.num_instances() - p
        );
    }
    println!("  explored {} operating points", g.curve.len());
    if args.has("curve") {
        for p in &g.curve {
            println!(
                "    {:>8.3} req/s -> attainment {:>5.1}%",
                p.rate,
                p.attainment * 100.0
            );
        }
    }
    Ok(())
}

/// Shared `--level p50|p90|p99` parsing (goodput + frontier + plan),
/// erroring loudly on a typo instead of silently defaulting.
fn parse_level(args: &Args) -> Result<Attainment> {
    let raw = args.get_or("level", "p90");
    Attainment::by_name(&raw)
        .ok_or_else(|| anyhow::anyhow!("--level expects p50|p90|p99, got '{raw}'"))
}

/// The goodput-frontier sweep (`frontier` subcommand).
fn cmd_frontier(args: &Args) -> Result<()> {
    let selected = select_scenarios(args)?;
    let systems = select_systems(args)?;
    let level = parse_level(args)?;

    let base = scenarios::ScenarioConfig {
        deployment: deployment_from_args(args)?,
        seed: args.u64_flag("seed").map_err(Error::msg)?.unwrap_or(42),
        rate: None, // the search owns the rate
        duration_override: args.f64_flag("duration").map_err(Error::msg)?,
        fault_seed: args.u64_flag("fault-seed").map_err(Error::msg)?,
        trace: false, // probes never trace; --trace-out reruns the frontier point
    };
    let mut cfg = frontier::FrontierConfig::new(base, level);
    cfg.autoscale = args.has("autoscale");
    cfg.quick = args.has("quick");
    // Doomed probes abort as soon as the verdict is decided; --no-abandon
    // runs every probe to completion (results are bit-identical — the
    // flag only changes simulator cost, and exists for exactly that
    // comparison).
    cfg.early_abandon = !args.has("no-abandon");
    // Bisection probes speculate ahead on the worker pool by default;
    // --no-speculate probes one rate at a time (answers are bit-identical
    // either way — the flag exists to measure the speedup and to debug
    // with a single-threaded probe stream).
    cfg.speculate = !args.has("no-speculate");
    // Per-cell wall-clock cap: truncated cells report their confirmed
    // rate and are flagged in BENCH_simperf.json.
    cfg.budget_s = args.f64_flag("budget-s").map_err(Error::msg)?;
    if cfg.autoscale && !systems.contains(&SystemKind::EcoServe) {
        // Otherwise the BENCH report would claim autoscale_variant=true
        // while containing no mitosis row.
        bail!(
            "--autoscale adds a mitosis-on PaDG variant, but the selected \
             --system excludes ecoserve; drop --system or use --system ecoserve"
        );
    }

    let d = &cfg.base.deployment;
    let variants = if cfg.autoscale { " (+ mitosis-on PaDG variant)" } else { "" };
    println!(
        "goodput frontier: {} scenario(s) x {} system(s){} at {} on {} x{} instances (TP={}) / {}",
        selected.len(),
        systems.len(),
        variants,
        level.label(),
        d.model.name,
        d.num_instances(),
        d.tp,
        d.cluster.name,
    );
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let t0 = std::time::Instant::now();
    let fronts = frontier::run_frontier(&selected, &cfg, &systems, workers);
    let wall = t0.elapsed();
    for f in &fronts {
        println!();
        print!("{}", frontier::render_frontier_table(f));
    }
    println!("\ntotal wall clock: {:.1}s", wall.as_secs_f64());
    let (events, saved, abandoned): (u64, u64, usize) = fronts
        .iter()
        .flat_map(|f| &f.rows)
        .fold((0, 0, 0), |acc, c| {
            (
                acc.0 + c.perf.events,
                acc.1 + c.perf.events_saved,
                acc.2 + c.perf.abandoned_probes,
            )
        });
    println!(
        "simulated {events} events; {abandoned} probe(s) abandoned early, \
         saving >= {saved} queued events"
    );

    if let Some(path) = args.get("out") {
        let json = frontier::frontier_to_json(&fronts, &cfg, wall).to_string();
        std::fs::write(path, &json)
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("wrote BENCH report to {path}");
    }
    if let Some(path) = args.get("perf-out") {
        let json = frontier::simperf_to_json(&fronts, &cfg, wall).to_string();
        std::fs::write(path, &json)
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("wrote simperf report to {path}");
    }
    if let Some(path) = args.get_path("trace-out").map_err(Error::msg)? {
        // Search probes run recorder-off (cheap, bit-identical); the
        // flight recorder rides one confirmation run per scenario at the
        // frontier's own operating point — the best cell's confirmed max
        // rate (scenario default when nothing was sustained).
        let mut traced = cfg.base.clone();
        traced.trace = true;
        let outcomes: Vec<scenarios::ScenarioOutcome> = fronts
            .iter()
            .map(|f| {
                traced.rate = Some(match f.best() {
                    Some(best) if best.max_rate > 0.0 => best.max_rate,
                    _ => f.scenario.default_rate,
                });
                scenarios::run_scenario(&f.scenario, &traced, &systems)
            })
            .collect();
        write_trace_artifacts(&outcomes, &traced, &path)?;
    }
    Ok(())
}

/// The capacity planner (`plan` subcommand): goodput-per-dollar search
/// over the deployment space for one workload.
fn cmd_plan(args: &Args) -> Result<()> {
    let mut selected = select_scenarios(args)?;
    if args.get("scenario").is_none()
        && args.get_path("replay").ok().flatten().is_none()
        && args.get_path("import").ok().flatten().is_none()
    {
        bail!(
            "plan needs one workload: --scenario <name>, --replay <log>, \
             or --import <file> --format <name>"
        );
    }
    let scenario = selected.remove(0);
    let model = ModelSpec::by_name(&args.get_or("model", "codellama-34b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let clusters = match args.get_or("cluster", "l20").as_str() {
        "all" => vec![ClusterSpec::l20_cluster(), ClusterSpec::a800_cluster()],
        name => vec![ClusterSpec::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown cluster '{name}' (l20|a800|all)"))?],
    };

    let mut cfg = if args.has("quick") {
        ecoserve::planner::PlanConfig::quick(scenario, model)
    } else {
        ecoserve::planner::PlanConfig::new(scenario, model)
    };
    cfg.clusters = clusters;
    cfg.level = parse_level(args)?;
    cfg.seed = args.u64_flag("seed").map_err(Error::msg)?.unwrap_or(42);
    cfg.fault_seed = args.u64_flag("fault-seed").map_err(Error::msg)?;
    cfg.target_rate = args.f64_flag("target-rate").map_err(Error::msg)?;
    cfg.budget_s = args.f64_flag("budget-s").map_err(Error::msg)?;
    cfg.duration_override = args.f64_flag("duration").map_err(Error::msg)?;
    cfg.spot = args.has("spot");
    if let Some(g) = args.usize_flag("gpus").map_err(Error::msg)? {
        cfg.max_gpus = Some(g);
    }
    if let Some(name) = args.get("system") {
        cfg.systems = vec![SystemKind::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown system '{name}'"))?];
    }

    let candidates = ecoserve::planner::enumerate_candidates(&cfg);
    if candidates.is_empty() {
        bail!(
            "no feasible candidate: {} does not fit the GPU budget on {} \
             (raise --gpus or pick a bigger cluster)",
            cfg.model.name,
            cfg.clusters.iter().map(|c| c.name).collect::<Vec<_>>().join(",")
        );
    }
    println!(
        "capacity plan: {} candidate(s) for '{}' ({} at {}) across {} cluster tier(s) \
         x {} system(s)",
        candidates.len(),
        cfg.scenario.name,
        cfg.model.name,
        cfg.level.label(),
        cfg.clusters.len(),
        cfg.systems.len(),
    );
    let outcome = ecoserve::planner::run_plan_on(&cfg, candidates);
    println!();
    print!("{}", ecoserve::planner::render_plan_table(&outcome));
    println!("\ntotal wall clock: {:.1}s", outcome.wall.as_secs_f64());

    if let Some(path) = args.get("out") {
        let json = ecoserve::planner::plan_to_json(&outcome, &cfg, outcome.wall).to_string();
        std::fs::write(path, &json)
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("wrote BENCH plan report to {path}");
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let b = args.get_f64("batch", 8.0);
    let s = args.get_f64("seq", 512.0);
    let h = args.get_f64("hidden", 8192.0);
    let m = args.get_f64("heads", 64.0);
    println!("Table 2 — arithmetic intensity (B={b}, S={s}, H={h}, M={m}, bf16)");
    println!("{:<22} {:>8} {:>14} {:>16} {:>10}", "Operation", "Phase", "GFLOPs", "MBytes", "AI");
    for op in perfmodel::table2_ops(b, s, h, m, 2.0) {
        println!(
            "{:<22} {:>8} {:>14.2} {:>16.2} {:>10.1}",
            op.name,
            format!("{:?}", op.phase),
            op.flops / 1e9,
            op.bytes / 1e6,
            op.arithmetic_intensity()
        );
    }
    Ok(())
}

fn cmd_table3() -> Result<()> {
    use ecoserve::perfmodel::interconnect::required_kv_bandwidth;
    use ecoserve::perfmodel::parallelism::ParallelCfg;
    use ecoserve::perfmodel::{BatchTimer, GpuSpec};
    println!("Table 3 — KV generation rate and required transfer bandwidth");
    println!("{:<16} {:>6} {:>12} {:>22}", "Model", "GPU", "Tokens/s", "Required bandwidth");
    for (model, gpu, tp) in [
        (ModelSpec::llama_30b(), GpuSpec::l20(), 4),
        (ModelSpec::llama_30b(), GpuSpec::a800(), 2),
        (ModelSpec::codellama_34b(), GpuSpec::l20(), 4),
        (ModelSpec::codellama_34b(), GpuSpec::a800(), 2),
    ] {
        let link = ecoserve::perfmodel::interconnect::LinkSpec::pcie4();
        let timer = BatchTimer::new(model.clone(), gpu.clone(),
                                    ParallelCfg::tp_only(tp, link));
        let instances_per_node = 8 / tp;
        let toks = timer.prefill_tokens_per_sec(1024) * instances_per_node as f64;
        let bw = required_kv_bandwidth(toks, model.kv_bytes_per_token());
        println!(
            "{:<16} {:>6} {:>12.1} {:>18.2} GB/s",
            model.name, gpu.name, toks, bw / 1e9
        );
    }
    Ok(())
}
