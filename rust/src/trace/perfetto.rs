//! Chrome/Perfetto `trace_event` export of a flight-recorder log.
//!
//! The JSON object format (`{"traceEvents": [...]}`) is understood by
//! both `chrome://tracing` and https://ui.perfetto.dev — drag the file
//! in. Mapping:
//! * each named track (one per scenario × system) becomes a *process*,
//!   labeled via `"M"` (metadata) events;
//! * thread 0 carries request-lifecycle and cluster-wide instants
//!   (`"i"` events, thread-scoped);
//! * thread `1 + i` carries instance `i`'s phase windows, per-request
//!   prefill spans, KV-transfer spans (`"X"` complete events) and its
//!   health instants;
//! * timestamps are microseconds (`ts`/`dur`), per the spec.
//!
//! Everything is built through [`crate::util::json::Json`] (objects are
//! `BTreeMap`s), so serialization is deterministic — the CI determinism
//! lock diffs two same-seed exports byte-for-byte.

use super::{TraceEvent, TraceKind, NO_INSTANCE, NO_REQ};
use crate::util::json::Json;

/// Lifecycle + cluster-wide events render on this thread id.
const LIFECYCLE_TID: u32 = 0;

fn tid_for(ev: &TraceEvent) -> u32 {
    if ev.instance == NO_INSTANCE {
        LIFECYCLE_TID
    } else {
        1 + ev.instance
    }
}

fn event_name(ev: &TraceEvent) -> String {
    match ev.kind {
        TraceKind::Reject(cause) => format!("reject:{}", cause.label()),
        kind => kind.label().to_string(),
    }
}

fn push_event(out: &mut Vec<Json>, pid: u32, ev: &TraceEvent) {
    let mut fields = vec![
        ("name", Json::str(event_name(ev))),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid_for(ev) as f64)),
        ("ts", Json::num(ev.t0 * 1e6)),
    ];
    if ev.is_instant() {
        fields.push(("ph", Json::str("i")));
        fields.push(("s", Json::str("t")));
    } else {
        fields.push(("ph", Json::str("X")));
        fields.push(("dur", Json::num((ev.t1 - ev.t0) * 1e6)));
    }
    if ev.id != NO_REQ {
        fields.push(("args", Json::obj(vec![("id", Json::num(ev.id as f64))])));
    }
    out.push(Json::obj(fields));
}

fn push_meta(out: &mut Vec<Json>, pid: u32, tid: Option<u32>, key: &str, name: &str) {
    let mut fields = vec![
        ("name", Json::str(key)),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ];
    if let Some(tid) = tid {
        fields.push(("tid", Json::num(tid as f64)));
    }
    out.push(Json::obj(fields));
}

/// Render named tracks (label, event log) as one Perfetto JSON document.
pub fn to_perfetto(tracks: &[(String, &[TraceEvent])]) -> Json {
    let mut out: Vec<Json> = Vec::new();
    for (i, (label, events)) in tracks.iter().enumerate() {
        let pid = 1 + i as u32;
        push_meta(&mut out, pid, None, "process_name", label);
        push_meta(&mut out, pid, Some(LIFECYCLE_TID), "thread_name", "lifecycle");
        // Name each instance thread that actually appears.
        let mut seen: Vec<u32> = events
            .iter()
            .filter(|e| e.instance != NO_INSTANCE)
            .map(|e| e.instance)
            .collect();
        seen.sort_unstable();
        seen.dedup();
        for inst in seen {
            push_meta(
                &mut out,
                pid,
                Some(1 + inst),
                "thread_name",
                &format!("instance {inst}"),
            );
        }
        for ev in *events {
            push_event(&mut out, pid, ev);
        }
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(out)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::RejectCause;

    fn sample() -> Vec<TraceEvent> {
        vec![
            TraceEvent::instant(TraceKind::Arrive, 7, NO_INSTANCE, 1.0),
            TraceEvent::span(TraceKind::ReqPrefill, 7, 2, 1.0, 1.5),
            TraceEvent::span(TraceKind::PhasePrefill, NO_REQ, 2, 1.0, 1.5),
            TraceEvent::instant(TraceKind::Reject(RejectCause::QueueFull), 9, NO_INSTANCE, 2.0),
        ]
    }

    #[test]
    fn export_parses_and_maps_tracks() {
        let evs = sample();
        let doc = to_perfetto(&[("steady/ecoserve".to_string(), evs.as_slice())]);
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("valid JSON");
        let tes = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 metadata (process + lifecycle thread + instance 2 thread) + 4.
        assert_eq!(tes.len(), 7);
        let arrive = tes.iter().find(|e| e.get("name").unwrap().as_str() == Some("arrive"));
        let a = arrive.expect("arrive instant present");
        assert_eq!(a.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(a.get("tid").unwrap().as_i64(), Some(0));
        assert_eq!(a.get("ts").unwrap().as_f64(), Some(1e6));
        let span = tes.iter().find(|e| e.get("name").unwrap().as_str() == Some("req_prefill"));
        let s = span.expect("prefill span present");
        assert_eq!(s.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(s.get("tid").unwrap().as_i64(), Some(3));
        assert_eq!(s.get("dur").unwrap().as_f64(), Some(0.5e6));
        assert!(tes.iter().any(|e| e.get("name").unwrap().as_str() == Some("reject:queue_full")));
    }

    #[test]
    fn export_is_deterministic() {
        let evs = sample();
        let tracks = vec![("a".to_string(), evs.as_slice()), ("b".to_string(), evs.as_slice())];
        let one = to_perfetto(&tracks).to_string();
        let two = to_perfetto(&tracks).to_string();
        assert_eq!(one, two);
    }
}
