//! Flight recorder: typed spans and instants for per-request causal
//! tracing and per-instance phase timelines.
//!
//! The recorder is *zero-cost when off*: the [`crate::metrics::Collector`]
//! hosts an `Option<TraceSink>` (default `None`), every hook is an
//! inlined no-op without a sink, and attaching one changes no simulation
//! decision — recorder-off runs stay bit-identical and allocation-free on
//! the warm path (the PR 8/9 locks). With a sink attached, the engine,
//! the coordinator, all four baselines, the client loop, and the fault
//! layer append fixed-size [`TraceEvent`]s into one grow-only `Vec`
//! that retains capacity across runs, so a warmed sink re-attached to an
//! identical run allocates nothing.
//!
//! Two derived surfaces consume the event log:
//! * [`perfetto`] renders it as Chrome/Perfetto `trace_event` JSON for
//!   visual inspection (one track per instance, one per lifecycle);
//! * [`report`] computes the diagnostics behind `BENCH_trace.json` —
//!   per-class SLO-miss attribution, the prefill-availability gap
//!   (rolling activation's invariant, measured rather than assumed), and
//!   the per-instance phase-overlap fraction (temporal-disaggregation
//!   purity).

pub mod perfetto;
pub mod report;

pub use perfetto::to_perfetto;
pub use report::{summarize, ClassMisses, TraceCapture, TraceSummary};

/// `TraceEvent::id` for events not tied to a request (phase windows,
/// instance health transitions, link faults).
pub const NO_REQ: u64 = u64::MAX;

/// `TraceEvent::instance` for events not tied to an instance (request
/// lifecycle instants, link-wide faults).
pub const NO_INSTANCE: u32 = u32::MAX;

/// Why a request was shed or rejected. Tagging the cause at the shed
/// site is what makes the miss-attribution histogram causal instead of
/// inferred.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectCause {
    /// A baseline's bounded admission queue was full.
    QueueFull,
    /// PaDG deadline-aware admission: the head of the backlog had
    /// already outlived its TTFT budget.
    Deadline,
    /// PaDG priority shedding: a retry (or anything ranked below first
    /// attempts) was dropped to protect fresh work.
    Priority,
    /// PaDG backlog drain found the request hopeless (its TTFT budget
    /// had expired while queued).
    Hopeless,
    /// Untagged call sites (kept for API compatibility).
    Other,
}

impl RejectCause {
    pub fn label(&self) -> &'static str {
        match self {
            RejectCause::QueueFull => "queue_full",
            RejectCause::Deadline => "deadline",
            RejectCause::Priority => "priority",
            RejectCause::Hopeless => "hopeless",
            RejectCause::Other => "other",
        }
    }
}

/// What a [`TraceEvent`] records. Instants carry `t0 == t1`; spans carry
/// a closed window `[t0, t1]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceKind {
    // -- per-request lifecycle instants --
    /// First attempt arrived at the coordinator.
    Arrive,
    /// A client retry (id >= RETRY_ID_BASE) arrived.
    Retry,
    /// First output token (end of the request's TTFT clock, §3.3).
    FirstToken,
    /// Final output token.
    Complete,
    /// Shed/rejected at admission or drain, with the tagged cause.
    Reject(RejectCause),
    /// Brownout defense truncated the request's decode budget.
    Brownout,
    /// Evacuated off a dying instance and re-queued (fault re-route).
    Reroute,
    // -- per-request execution spans --
    /// The request's prompt ran in a prefill batch on `instance`.
    ReqPrefill,
    /// KV transfer between instances (FuDG prefill → decode handoff).
    Transfer,
    // -- per-instance phase windows (spans, coalesced) --
    /// The instance executed prefill batches over `[t0, t1]`.
    PhasePrefill,
    /// The instance executed decode iterations over `[t0, t1]`.
    PhaseDecode,
    /// Sarathi hybrid iterations (mixed prefill+decode) over `[t0, t1]`.
    PhaseHybrid,
    // -- per-instance state instants --
    /// A draining instance emptied and deactivated (mitosis scale-down
    /// completion or rolling-activation handoff).
    Drained,
    /// Fault layer: the instance died.
    Down,
    /// Fault layer: the instance came back (weights reloaded, KV cold).
    Up,
    /// Fault layer: spot preemption notice (still running, draining).
    PreemptNotice,
    /// Fault layer: interconnect degraded (cluster-wide).
    LinkDegrade,
    /// Fault layer: interconnect restored.
    LinkRestore,
    /// Mitosis: the coordinator activated this instance (scale-up).
    ScaleUp,
    /// Mitosis: the coordinator began draining this instance.
    ScaleDown,
}

impl TraceKind {
    pub fn label(&self) -> &'static str {
        match self {
            TraceKind::Arrive => "arrive",
            TraceKind::Retry => "retry",
            TraceKind::FirstToken => "first_token",
            TraceKind::Complete => "complete",
            TraceKind::Reject(_) => "reject",
            TraceKind::Brownout => "brownout",
            TraceKind::Reroute => "reroute",
            TraceKind::ReqPrefill => "req_prefill",
            TraceKind::Transfer => "transfer",
            TraceKind::PhasePrefill => "prefill",
            TraceKind::PhaseDecode => "decode",
            TraceKind::PhaseHybrid => "hybrid",
            TraceKind::Drained => "drained",
            TraceKind::Down => "down",
            TraceKind::Up => "up",
            TraceKind::PreemptNotice => "preempt_notice",
            TraceKind::LinkDegrade => "link_degrade",
            TraceKind::LinkRestore => "link_restore",
            TraceKind::ScaleUp => "scale_up",
            TraceKind::ScaleDown => "scale_down",
        }
    }

    /// Is this an instance phase window (eligible for coalescing)?
    pub fn is_phase(&self) -> bool {
        matches!(
            self,
            TraceKind::PhasePrefill | TraceKind::PhaseDecode | TraceKind::PhaseHybrid
        )
    }
}

/// One recorded event: fixed-size, `Copy`, no heap — the sink is a flat
/// `Vec<TraceEvent>` whose capacity survives [`TraceSink::clear`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub kind: TraceKind,
    /// Request id, or [`NO_REQ`].
    pub id: u64,
    /// Instance id, or [`NO_INSTANCE`].
    pub instance: u32,
    /// Start time (== `t1` for instants).
    pub t0: f64,
    /// End time.
    pub t1: f64,
}

impl TraceEvent {
    pub fn instant(kind: TraceKind, id: u64, instance: u32, t: f64) -> Self {
        TraceEvent { kind, id, instance, t0: t, t1: t }
    }

    pub fn span(kind: TraceKind, id: u64, instance: u32, t0: f64, t1: f64) -> Self {
        TraceEvent { kind, id, instance, t0, t1 }
    }

    pub fn is_instant(&self) -> bool {
        self.t0 == self.t1
    }
}

/// Back-to-back phase windows on one instance coalesce when the gap is
/// below this slack (floating-point wake jitter, not real idleness).
const COALESCE_SLACK_S: f64 = 1e-9;

/// The flight-recorder sink: an append-only event log plus per-instance
/// coalescing state so consecutive same-phase batch windows merge into
/// one span (a PaDG prefill window is one `PhasePrefill` event, not one
/// per batch). All buffers retain capacity across [`TraceSink::clear`].
#[derive(Debug, Default)]
pub struct TraceSink {
    events: Vec<TraceEvent>,
    /// Per-instance index+1 into `events` of the instance's most recent
    /// phase window (0 = none). Invalidated by `clear`.
    last_phase: Vec<usize>,
}

impl TraceSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Drop all events, keeping every buffer's capacity (the warm-path
    /// contract: a cleared sink re-attached to an identical run appends
    /// without allocating).
    pub fn clear(&mut self) {
        self.events.clear();
        self.last_phase.clear();
    }

    /// Append one event.
    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    /// Record an instance phase window `[t0, t1]`, merging with the
    /// instance's previous window when the kind matches and the windows
    /// abut (within [`COALESCE_SLACK_S`]).
    pub fn push_phase(&mut self, kind: TraceKind, instance: u32, t0: f64, t1: f64) {
        debug_assert!(kind.is_phase());
        let i = instance as usize;
        if i >= self.last_phase.len() {
            self.last_phase.resize(i + 1, 0);
        }
        if let Some(idx) = self.last_phase[i].checked_sub(1) {
            let prev = &mut self.events[idx];
            if prev.kind == kind && t0 <= prev.t1 + COALESCE_SLACK_S {
                prev.t1 = prev.t1.max(t1);
                return;
            }
        }
        self.events.push(TraceEvent::span(kind, NO_REQ, instance, t0, t1));
        self.last_phase[i] = self.events.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instants_and_spans_record_their_shape() {
        let mut s = TraceSink::new();
        s.push(TraceEvent::instant(TraceKind::Arrive, 7, NO_INSTANCE, 1.5));
        s.push(TraceEvent::span(TraceKind::ReqPrefill, 7, 2, 1.5, 1.9));
        assert_eq!(s.len(), 2);
        assert!(s.events()[0].is_instant());
        assert!(!s.events()[1].is_instant());
        assert_eq!(s.events()[1].instance, 2);
    }

    #[test]
    fn abutting_same_phase_windows_coalesce() {
        let mut s = TraceSink::new();
        s.push_phase(TraceKind::PhasePrefill, 0, 0.0, 1.0);
        s.push_phase(TraceKind::PhasePrefill, 0, 1.0, 2.0);
        s.push_phase(TraceKind::PhasePrefill, 0, 2.0 + 1e-12, 3.0);
        assert_eq!(s.len(), 1, "abutting windows must merge");
        assert_eq!(s.events()[0].t0, 0.0);
        assert_eq!(s.events()[0].t1, 3.0);
    }

    #[test]
    fn gaps_and_phase_changes_break_coalescing() {
        let mut s = TraceSink::new();
        s.push_phase(TraceKind::PhasePrefill, 0, 0.0, 1.0);
        s.push_phase(TraceKind::PhaseDecode, 0, 1.0, 2.0); // kind change
        s.push_phase(TraceKind::PhaseDecode, 0, 5.0, 6.0); // real gap
        assert_eq!(s.len(), 3);
        assert_eq!(s.events()[1].kind, TraceKind::PhaseDecode);
        assert_eq!(s.events()[2].t0, 5.0);
    }

    #[test]
    fn instances_coalesce_independently() {
        let mut s = TraceSink::new();
        s.push_phase(TraceKind::PhaseDecode, 0, 0.0, 1.0);
        s.push_phase(TraceKind::PhaseDecode, 3, 0.5, 1.5);
        s.push_phase(TraceKind::PhaseDecode, 0, 1.0, 2.0);
        s.push_phase(TraceKind::PhaseDecode, 3, 1.5, 2.5);
        assert_eq!(s.len(), 2, "one merged window per instance");
        assert_eq!(s.events()[0].t1, 2.0);
        assert_eq!(s.events()[1].t1, 2.5);
    }

    #[test]
    fn interleaved_non_phase_events_do_not_break_coalescing() {
        let mut s = TraceSink::new();
        s.push_phase(TraceKind::PhaseDecode, 1, 0.0, 1.0);
        s.push(TraceEvent::instant(TraceKind::FirstToken, 42, NO_INSTANCE, 0.5));
        s.push_phase(TraceKind::PhaseDecode, 1, 1.0, 2.0);
        assert_eq!(s.len(), 2);
        assert_eq!(s.events()[0].t1, 2.0);
    }

    #[test]
    fn clear_retains_capacity_and_resets_coalescing() {
        let mut s = TraceSink::new();
        for i in 0..64 {
            s.push_phase(TraceKind::PhaseDecode, 0, i as f64 * 2.0, i as f64 * 2.0 + 1.0);
        }
        let cap = s.events.capacity();
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.events.capacity(), cap);
        // After clear, the stale last_phase index must not resurrect.
        s.push_phase(TraceKind::PhaseDecode, 0, 0.0, 1.0);
        assert_eq!(s.len(), 1);
    }
}
