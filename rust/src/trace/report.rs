//! Derived diagnostics over a flight-recorder event log: the numbers
//! behind `BENCH_trace.json`.
//!
//! Three families, each tied to a paper claim:
//! * **Prefill-availability gap** — rolling activation's invariant
//!   (§2.3: some instance is always prefill-available) made measurable.
//!   Per first-attempt request arriving in the scoring window, the gap
//!   is `first_token − arrival` (the §3.3 strict reference point: it
//!   folds in admission queueing for NoDG systems and KV-transfer
//!   staging for FuDG ones — everything between "the request exists"
//!   and "prefill service actually completed"). Requests shed before
//!   serving are censored at the shed instant; requests never served
//!   are censored at the run horizon and counted in `unprefilled`.
//! * **Per-class SLO-miss attribution** — every missed request in the
//!   window is assigned one causal bucket, in priority order: `shed`
//!   (a tagged Reject event), `fault_rerouted` (evacuated off a dying
//!   instance), `brownout_truncated` (decode budget cut by the overload
//!   defense), `queued_behind_prefill` (TTFT blown, or never reached
//!   its first token), else `slow_decode` (TPOT blown).
//! * **Phase-overlap fraction** — temporal-disaggregation purity: the
//!   share of instance busy-time spent in hybrid (mixed-phase) batches.
//!   Exactly 0.0 for PaDG and the separate-batching baselines; > 0 for
//!   Sarathi-style chunked prefill.

use std::collections::{HashMap, HashSet};

use super::{TraceEvent, TraceKind};
use crate::metrics::{Collector, SloSpec};
use crate::util::percentile_sorted;
use crate::workload::RETRY_ID_BASE;

/// Per-class SLO-miss attribution histogram. Buckets partition `misses`
/// (each missed request lands in exactly one).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClassMisses {
    pub class: String,
    /// First-attempt arrivals in the scoring window.
    pub arrived: usize,
    /// Requests that missed their SLO pair (or never completed).
    pub misses: usize,
    /// Shed at admission or backlog drain (tagged Reject event).
    pub shed: usize,
    /// Evacuated off a faulted instance and re-queued.
    pub fault_rerouted: usize,
    /// Decode budget truncated by the brownout defense.
    pub brownout_truncated: usize,
    /// TTFT blown (or first token never produced): the request waited
    /// behind prefill-unavailable instances.
    pub queued_behind_prefill: usize,
    /// Served promptly but decoded too slowly (TPOT blown).
    pub slow_decode: usize,
}

/// Derived diagnostics over one system's event log.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSummary {
    /// Total recorded events.
    pub events: usize,
    /// First-attempt arrivals in the scoring window.
    pub requests: usize,
    /// Max prefill-availability gap (seconds) over window arrivals.
    pub max_prefill_gap_s: f64,
    /// P99 of the same distribution.
    pub p99_prefill_gap_s: f64,
    /// Window arrivals never served and never shed (gap censored at the
    /// run horizon — the "unbounded under burst" signature).
    pub unprefilled: usize,
    /// Hybrid-batch busy-time / total phase busy-time.
    pub phase_overlap_frac: f64,
    /// Coalesced instance phase windows in the log.
    pub phase_windows: usize,
    pub classes: Vec<ClassMisses>,
}

/// A harvested recorder: the raw event log plus its derived summary,
/// carried on `SystemRow` when tracing is on.
#[derive(Debug, Clone)]
pub struct TraceCapture {
    pub events: Vec<TraceEvent>,
    pub summary: TraceSummary,
}

/// Compute the derived diagnostics for one run. `warmup..t_end` is the
/// scoring window (same bounds the scenario scorer uses), `horizon` the
/// run end (censoring point for never-served requests), `classes` the
/// per-class SLO table, and `class_of` the workload's id → class map.
pub fn summarize(
    events: &[TraceEvent],
    metrics: &Collector,
    warmup: f64,
    t_end: f64,
    horizon: f64,
    classes: &[(String, SloSpec)],
    class_of: &dyn Fn(u64) -> usize,
) -> TraceSummary {
    // Pass 1: per-request lifecycle maps + phase-time totals.
    let mut arrive: HashMap<u64, f64> = HashMap::new();
    let mut first: HashMap<u64, f64> = HashMap::new();
    let mut reject: HashMap<u64, f64> = HashMap::new();
    let mut brownout: HashSet<u64> = HashSet::new();
    let mut reroute: HashSet<u64> = HashSet::new();
    let (mut prefill_s, mut decode_s, mut hybrid_s) = (0.0f64, 0.0f64, 0.0f64);
    let mut phase_windows = 0usize;
    for ev in events {
        match ev.kind {
            TraceKind::Arrive => {
                if ev.t0 >= warmup && ev.t0 < t_end && ev.id < RETRY_ID_BASE {
                    arrive.entry(ev.id).or_insert(ev.t0);
                }
            }
            TraceKind::FirstToken => {
                first.entry(ev.id).or_insert(ev.t0);
            }
            TraceKind::Reject(_) => {
                reject.entry(ev.id).or_insert(ev.t0);
            }
            TraceKind::Brownout => {
                brownout.insert(ev.id);
            }
            TraceKind::Reroute => {
                reroute.insert(ev.id);
            }
            TraceKind::PhasePrefill => {
                prefill_s += ev.t1 - ev.t0;
                phase_windows += 1;
            }
            TraceKind::PhaseDecode => {
                decode_s += ev.t1 - ev.t0;
                phase_windows += 1;
            }
            TraceKind::PhaseHybrid => {
                hybrid_s += ev.t1 - ev.t0;
                phase_windows += 1;
            }
            _ => {}
        }
    }

    // Prefill-availability gaps, censored for shed / never-served.
    let mut gaps: Vec<f64> = Vec::with_capacity(arrive.len());
    let mut unprefilled = 0usize;
    for (&id, &t) in &arrive {
        let gap = match first.get(&id) {
            Some(&ft) => ft - t,
            None => match reject.get(&id) {
                Some(&rt) => rt - t,
                None => {
                    unprefilled += 1;
                    horizon - t
                }
            },
        };
        gaps.push(gap.max(0.0));
    }
    gaps.sort_by(f64::total_cmp);
    let max_gap = gaps.last().copied().unwrap_or(0.0);
    let p99_gap = percentile_sorted(&gaps, 99.0);

    // Per-class miss attribution over the scoring window.
    let mut rows: Vec<ClassMisses> = classes
        .iter()
        .map(|(name, _)| ClassMisses { class: name.clone(), ..Default::default() })
        .collect();
    if !rows.is_empty() {
        let by_id: HashMap<u64, &crate::metrics::RequestRecord> =
            metrics.window_records(warmup, t_end).map(|r| (r.id, r)).collect();
        for &id in arrive.keys() {
            let c = class_of(id).min(rows.len() - 1);
            let slo = classes[c].1;
            let row = &mut rows[c];
            row.arrived += 1;
            if let Some(rec) = by_id.get(&id) {
                if rec.meets(&slo) {
                    continue;
                }
                row.misses += 1;
                if reroute.contains(&id) {
                    row.fault_rerouted += 1;
                } else if brownout.contains(&id) {
                    row.brownout_truncated += 1;
                } else if rec.ttft() > slo.ttft {
                    row.queued_behind_prefill += 1;
                } else {
                    row.slow_decode += 1;
                }
            } else if reject.contains_key(&id) {
                row.misses += 1;
                row.shed += 1;
            } else {
                // Neither completed nor shed inside the horizon.
                row.misses += 1;
                if reroute.contains(&id) {
                    row.fault_rerouted += 1;
                } else if first.contains_key(&id) {
                    row.slow_decode += 1;
                } else {
                    row.queued_behind_prefill += 1;
                }
            }
        }
    }

    let phase_total = prefill_s + decode_s + hybrid_s;
    TraceSummary {
        events: events.len(),
        requests: arrive.len(),
        max_prefill_gap_s: max_gap,
        p99_prefill_gap_s: p99_gap,
        unprefilled,
        phase_overlap_frac: if phase_total > 0.0 { hybrid_s / phase_total } else { 0.0 },
        phase_windows,
        classes: rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{RejectCause, NO_INSTANCE, NO_REQ};
    use crate::workload::Request;

    fn arrive(id: u64, t: f64) -> TraceEvent {
        TraceEvent::instant(TraceKind::Arrive, id, NO_INSTANCE, t)
    }

    fn ft(id: u64, t: f64) -> TraceEvent {
        TraceEvent::instant(TraceKind::FirstToken, id, NO_INSTANCE, t)
    }

    fn classes() -> Vec<(String, SloSpec)> {
        vec![("chat".to_string(), SloSpec::new(1.0, 0.1))]
    }

    /// Drive a collector through arrivals/completions so the attribution
    /// pass sees real records.
    fn collect(recs: &[(u64, f64, f64, f64)]) -> Collector {
        let mut c = Collector::new();
        for &(id, arrival, first, done) in recs {
            c.on_arrival(&Request { id, arrival, input_len: 10, output_len: 5 });
            c.on_first_token(id, first);
            c.on_token(id, (first + done) / 2.0);
            c.on_complete(id, done);
        }
        c
    }

    #[test]
    fn gap_is_arrival_to_first_token() {
        let m = collect(&[(1, 10.0, 10.4, 11.0), (2, 12.0, 14.0, 15.0)]);
        let evs =
            vec![arrive(1, 10.0), ft(1, 10.4), arrive(2, 12.0), ft(2, 14.0)];
        let s = summarize(&evs, &m, 0.0, 100.0, 200.0, &classes(), &|_| 0);
        assert_eq!(s.requests, 2);
        assert!((s.max_prefill_gap_s - 2.0).abs() < 1e-12);
        assert_eq!(s.unprefilled, 0);
    }

    #[test]
    fn shed_requests_censor_the_gap_at_the_shed_instant() {
        let m = collect(&[]);
        let evs = vec![
            arrive(1, 10.0),
            TraceEvent::instant(TraceKind::Reject(RejectCause::QueueFull), 1, NO_INSTANCE, 10.5),
        ];
        let s = summarize(&evs, &m, 0.0, 100.0, 200.0, &classes(), &|_| 0);
        assert!((s.max_prefill_gap_s - 0.5).abs() < 1e-12);
        assert_eq!(s.unprefilled, 0);
        assert_eq!(s.classes[0].shed, 1);
        assert_eq!(s.classes[0].misses, 1);
    }

    #[test]
    fn never_served_requests_censor_at_the_horizon() {
        let m = collect(&[]);
        let evs = vec![arrive(1, 50.0)];
        let s = summarize(&evs, &m, 0.0, 100.0, 200.0, &classes(), &|_| 0);
        assert_eq!(s.unprefilled, 1);
        assert!((s.max_prefill_gap_s - 150.0).abs() < 1e-12);
        assert_eq!(s.classes[0].queued_behind_prefill, 1);
    }

    #[test]
    fn retries_and_out_of_window_arrivals_are_excluded() {
        let m = collect(&[]);
        let evs = vec![
            arrive(RETRY_ID_BASE + 1, 10.0), // retry: excluded
            arrive(1, 5.0),                  // before warmup: excluded
            arrive(2, 100.0),                // after window end: excluded
        ];
        let s = summarize(&evs, &m, 8.0, 100.0, 200.0, &classes(), &|_| 0);
        assert_eq!(s.requests, 0);
        assert_eq!(s.max_prefill_gap_s, 0.0);
    }

    #[test]
    fn miss_attribution_buckets_partition_misses() {
        // id 1 meets; id 2 blows TTFT; id 3 blows TPOT only; id 4 was
        // rerouted and misses; id 5 brownout-truncated and misses TPOT.
        let m = collect(&[
            (1, 10.0, 10.4, 11.0),
            (2, 11.0, 13.0, 14.0),
            (3, 12.0, 12.3, 20.0),
            (4, 13.0, 16.0, 17.0),
            (5, 14.0, 14.2, 22.0),
        ]);
        let mut evs: Vec<TraceEvent> = (1..=5).map(|i| arrive(i, 9.0 + i as f64)).collect();
        evs.push(TraceEvent::instant(TraceKind::Reroute, 4, NO_INSTANCE, 15.0));
        evs.push(TraceEvent::instant(TraceKind::Brownout, 5, NO_INSTANCE, 14.1));
        let s = summarize(&evs, &m, 0.0, 100.0, 200.0, &classes(), &|_| 0);
        let c = &s.classes[0];
        assert_eq!(c.arrived, 5);
        assert_eq!(c.misses, 4);
        assert_eq!(c.queued_behind_prefill, 1);
        assert_eq!(c.slow_decode, 1);
        assert_eq!(c.fault_rerouted, 1);
        assert_eq!(c.brownout_truncated, 1);
        assert_eq!(
            c.misses,
            c.shed + c.fault_rerouted + c.brownout_truncated + c.queued_behind_prefill
                + c.slow_decode
        );
    }

    #[test]
    fn phase_overlap_fraction_counts_hybrid_share() {
        let m = collect(&[]);
        let evs = vec![
            TraceEvent::span(TraceKind::PhasePrefill, NO_REQ, 0, 0.0, 1.0),
            TraceEvent::span(TraceKind::PhaseDecode, NO_REQ, 0, 1.0, 3.0),
            TraceEvent::span(TraceKind::PhaseHybrid, NO_REQ, 1, 0.0, 1.0),
        ];
        let s = summarize(&evs, &m, 0.0, 100.0, 200.0, &[], &|_| 0);
        assert!((s.phase_overlap_frac - 0.25).abs() < 1e-12);
        assert_eq!(s.phase_windows, 3);
        // No phase events at all → 0, not NaN.
        let s2 = summarize(&[], &m, 0.0, 100.0, 200.0, &[], &|_| 0);
        assert_eq!(s2.phase_overlap_frac, 0.0);
    }
}
