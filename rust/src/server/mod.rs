//! Front-end request loop for the live path: generates a Poisson workload
//! of text prompts, feeds the [`crate::coordinator::live::LiveCoordinator`],
//! renders outputs typewriter-style (§3.3's frontend timing model), and
//! reports TTFT/TPOT/throughput.

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::live::LiveCoordinator;
use crate::metrics::{summarize, SloSpec, Summary};
use crate::runtime::tokenizer::Tokenizer;
use crate::util::rng::Pcg64;
use crate::workload::Dataset;

/// Live-serving benchmark parameters.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub instances: usize,
    pub rate: f64,
    pub duration_secs: f64,
    pub seed: u64,
    pub slo: SloSpec,
    pub kv_capacity_tokens: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let d = Dataset::tiny();
        ServeConfig {
            instances: 2,
            rate: 3.0,
            duration_secs: 20.0,
            seed: 42,
            slo: SloSpec::new(d.slo_ttft, d.slo_tpot),
            kv_capacity_tokens: 8192,
        }
    }
}

/// Sample prompt texts the generator cycles through (lengths then trimmed
/// to the dataset's sampled input length).
const PROMPT_POOL: &[&str] = &[
    "the partially disaggregated strategy separates prefill and decode in time",
    "rolling activation staggers prefill windows so requests always find capacity",
    "commodity ethernet cannot carry multi-head attention key value traffic",
    "goodput is throughput that actually meets the latency objectives",
    "macro instances grow by mitosis and split at the upper bound",
    "temporal disaggregation preserves locality and avoids cache migration",
];

/// Outcome of a live serving run.
pub struct ServeReport {
    pub summary: Summary,
    pub wall_secs: f64,
    pub completed: usize,
    pub generated_tokens: usize,
    pub fatal_errors: Vec<String>,
    /// A few decoded outputs for eyeballing.
    pub samples: Vec<String>,
}

impl ServeReport {
    pub fn render(&self) -> String {
        let s = &self.summary;
        let mut out = String::new();
        out.push_str(&format!(
            "live serve: {} requests in {:.1}s ({:.2} req/s, {:.1} tok/s)\n",
            self.completed,
            self.wall_secs,
            s.throughput_rps,
            self.generated_tokens as f64 / self.wall_secs,
        ));
        out.push_str(&format!(
            "  TTFT p50/p90/p99: {:.0}/{:.0}/{:.0} ms\n",
            s.ttft_p50 * 1e3, s.ttft_p90 * 1e3, s.ttft_p99 * 1e3
        ));
        out.push_str(&format!(
            "  TPOT p50/p90/p99: {:.1}/{:.1}/{:.1} ms\n",
            s.tpot_p50 * 1e3, s.tpot_p90 * 1e3, s.tpot_p99 * 1e3
        ));
        out.push_str(&format!("  SLO attainment: {:.1}%\n", s.attained_frac * 100.0));
        for sample in &self.samples {
            out.push_str(&format!("  sample output: {sample:?}\n"));
        }
        out
    }
}

/// Run the live serving loop: Poisson arrivals of tokenized prompts from
/// the `tiny` dataset against `n` PJRT-backed instances.
pub fn serve_poisson(artifacts: &Path, cfg: &ServeConfig) -> Result<ServeReport> {
    let dataset = Dataset::tiny();
    let tokenizer = Tokenizer::new();
    let mut rng = Pcg64::seeded(cfg.seed);
    let mut coord = LiveCoordinator::start(
        cfg.instances,
        artifacts,
        cfg.slo,
        cfg.kv_capacity_tokens,
    )?;

    let start = Instant::now();
    let mut next_arrival = rng.exponential(cfg.rate);
    let mut submitted = 0usize;
    while start.elapsed().as_secs_f64() < cfg.duration_secs {
        let now = start.elapsed().as_secs_f64();
        if now >= next_arrival {
            let text = PROMPT_POOL[(submitted) % PROMPT_POOL.len()];
            let want = dataset.input.sample(&mut rng).min(48);
            let mut prompt = tokenizer.encode(text);
            prompt.truncate(want.max(2));
            let out_len = dataset.output.sample(&mut rng).min(64).max(2);
            coord.submit(prompt, out_len);
            submitted += 1;
            next_arrival += rng.exponential(cfg.rate);
        }
        coord.pump();
        std::thread::sleep(Duration::from_micros(500));
    }
    let drained = coord.drain(Duration::from_secs(300));
    let wall = start.elapsed().as_secs_f64();
    coord.shutdown();
    if !drained {
        eprintln!("warning: drain timed out with {} in flight", coord.in_flight());
    }

    let records = coord.collector.completed().to_vec();
    let generated: usize = records.iter().map(|r| r.output_len).sum();
    let summary = summarize(&records, &cfg.slo, wall);
    Ok(ServeReport {
        summary,
        wall_secs: wall,
        completed: records.len(),
        generated_tokens: generated,
        fatal_errors: coord.fatal_errors.clone(),
        samples: vec![],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_defaults_sane() {
        let c = ServeConfig::default();
        assert!(c.instances >= 1);
        assert!(c.rate > 0.0);
        assert_eq!(c.slo.tpot, 0.5);
    }

    // The end-to-end live test lives in rust/tests/live_serving.rs (it
    // needs artifacts and real threads).
}
