//! Online SLO verdict monitor: proves, mid-run, the moment a probe's
//! attainment target becomes mathematically unreachable.
//!
//! A rate probe's verdict is "does strict attainment (met / arrived, with
//! never-completed arrivals as violations) reach the target?". Two kinds
//! of violation are *guaranteed* before the run ends:
//!
//! * a measurement-window arrival whose TTFT deadline has passed with no
//!   first token — any future first token would already be late;
//! * a decoding request whose TPOT budget has run out with no completion —
//!   the request needs `slo.tpot · (output_len − 1)` seconds after its
//!   first token, and once that much time has passed any future completion
//!   already averages over budget (the simulator's oracle `output_len` is
//!   exact, so the deadline is, too);
//! * a completed request whose recorded latencies miss its SLO pair.
//!
//! The monitor counts those per traffic class as they become inevitable.
//! Once any class's best-possible attainment (every still-open request
//! assumed to meet its SLOs) drops below the target, the verdict is
//! decided: no continuation of the run can pass. [`Collector`] latches a
//! scoring snapshot at that instant, so a run abandoned there and a run
//! driven to completion report bit-identical numbers — the optimization
//! changes cost, never answers.
//!
//! Violation checks reuse the exact comparisons of
//! [`RequestRecord::meets`], so the online verdict can never contradict
//! the post-hoc scoring.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use super::{RequestRecord, SloSpec};

/// Probe-abandonment policy: the attainment target the online monitor
/// proves unreachable, and whether the engine should actually stop there
/// (`stop_early: false` still arms the monitor — the scoring snapshot is
/// latched either way, which is what makes the two modes bit-identical).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AbandonPolicy {
    /// Attainment fraction every class must sustain (e.g. 0.90 for P90).
    pub target: f64,
    /// Abort the simulation once the verdict is decided.
    pub stop_early: bool,
}

impl AbandonPolicy {
    /// Monitor and abort: the production frontier setting.
    pub fn stop_at(target: f64) -> Self {
        AbandonPolicy { target, stop_early: true }
    }

    /// Monitor only: run the full simulation but score through the same
    /// decision snapshot. The equivalence baseline for abandonment.
    pub fn monitor_only(target: f64) -> Self {
        AbandonPolicy { target, stop_early: false }
    }
}

/// One watched measurement-window arrival.
#[derive(Debug, Clone, Copy)]
struct Tracked {
    class: usize,
    arrival: f64,
    slo: SloSpec,
    /// Oracle generation length (the simulator knows it; schedulers don't).
    /// Arms the decode-phase TPOT deadline once the first token is timely.
    output_len: usize,
    /// Time of a first token that arrived within its deadline; the TTFT
    /// check is then settled and only the TPOT budget remains.
    first_token: Option<f64>,
}

/// Which exact check a heap entry schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum DeadlineKind {
    /// `now - arrival > slo.ttft` with no first token yet.
    Ttft,
    /// `now - first_token > slo.tpot · (output_len - 1)` with no
    /// completion yet (armed by a timely first token).
    Tpot,
}

/// Min-heap entry: approximate deadline used to schedule the exact
/// per-request check (the check itself recomputes the elapsed time so it
/// bit-matches [`RequestRecord::meets`]).
#[derive(Debug)]
struct Deadline {
    at: f64,
    id: u64,
    kind: DeadlineKind,
}

impl PartialEq for Deadline {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.id == other.id && self.kind == other.kind
    }
}
impl Eq for Deadline {}
impl PartialOrd for Deadline {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Deadline {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at
            .total_cmp(&other.at)
            .then(self.id.cmp(&other.id))
            .then(self.kind.cmp(&other.kind))
    }
}

/// Counts guaranteed SLO violations per class as they become inevitable
/// and decides when the attainment target is out of reach.
#[derive(Debug)]
pub struct SloMonitor {
    target: f64,
    /// Window arrivals registered per class (the attainment denominator).
    arrived: Vec<usize>,
    /// Guaranteed violations per class so far.
    violations: Vec<usize>,
    tracked: HashMap<u64, Tracked>,
    deadlines: BinaryHeap<Reverse<Deadline>>,
    decided_at: Option<f64>,
}

impl SloMonitor {
    pub fn new(target: f64, n_classes: usize) -> Self {
        SloMonitor {
            target,
            arrived: vec![0; n_classes],
            violations: vec![0; n_classes],
            tracked: HashMap::new(),
            deadlines: BinaryHeap::new(),
            decided_at: None,
        }
    }

    /// Register one measurement-window arrival before the run starts.
    /// Requests outside the window must not be tracked — they do not
    /// count toward strict attainment. `output_len` is the oracle
    /// generation length, which prices the decode-phase TPOT budget.
    pub fn track(&mut self, id: u64, arrival: f64, slo: SloSpec, class: usize, output_len: usize) {
        self.arrived[class] += 1;
        self.tracked
            .insert(id, Tracked { class, arrival, slo, output_len, first_token: None });
        self.deadlines
            .push(Reverse(Deadline { at: arrival + slo.ttft, id, kind: DeadlineKind::Ttft }));
    }

    /// Total window arrivals under watch.
    pub fn tracked_arrivals(&self) -> usize {
        self.arrived.iter().sum()
    }

    /// Guaranteed violations counted so far, across classes.
    pub fn violations(&self) -> usize {
        self.violations.iter().sum()
    }

    /// Has the target been proven unreachable?
    pub fn decided(&self) -> bool {
        self.decided_at.is_some()
    }

    /// Sim time at which the target became unreachable.
    pub fn decided_at(&self) -> Option<f64> {
        self.decided_at
    }

    fn violate(&mut self, class: usize, now: f64) {
        self.violations[class] += 1;
        if self.decided_at.is_none() {
            let arrived = self.arrived[class];
            // Best case: every not-yet-violated request meets its SLOs.
            let best = (arrived - self.violations[class]) as f64 / arrived as f64;
            // Same epsilon as the rate search's sustain test, so the
            // online verdict and the post-hoc verdict cannot disagree.
            if best < self.target - 1e-12 {
                self.decided_at = Some(now);
            }
        }
    }

    /// Advance the clock: any watched request whose first token could no
    /// longer arrive in time (`now - arrival > slo.ttft`) — or whose
    /// decode could no longer finish inside its TPOT budget
    /// (`(now - first_token) / (output_len - 1) > slo.tpot`) — is a
    /// guaranteed violation. Both are the exact [`RequestRecord::meets`]
    /// comparisons: a completion at any time `>= now` can only make the
    /// measured latency larger.
    pub fn advance(&mut self, now: f64) {
        loop {
            let (at, id, kind) = match self.deadlines.peek() {
                Some(Reverse(d)) => (d.at, d.id, d.kind),
                None => break,
            };
            if at > now {
                break;
            }
            self.deadlines.pop();
            // (class, blown?) for a still-live deadline; None when the
            // watch was already resolved (timely first token defuses
            // Ttft, completion defuses Tpot). Each check reuses the exact
            // floating-point expression of [`RequestRecord::meets`] — the
            // TTFT path its subtraction, the TPOT path its *division*
            // (`tpot() = (completion - first) / (out - 1)`): completion
            // can only land at or after `now` and both forms are monotone
            // in it, so a blown check here is blown in every future
            // scoring, bit for bit.
            let state = match (self.tracked.get(&id), kind) {
                (Some(t), DeadlineKind::Ttft) if t.first_token.is_none() => {
                    Some((t.class, now - t.arrival > t.slo.ttft))
                }
                (Some(t), DeadlineKind::Tpot) => t.first_token.map(|first| {
                    let m = t.output_len.saturating_sub(1).max(1) as f64;
                    (t.class, (now - first) / m > t.slo.tpot)
                }),
                _ => None,
            };
            match state {
                Some((class, true)) => {
                    self.tracked.remove(&id);
                    self.violate(class, now);
                }
                Some((_, false)) => {
                    // The heap key rounded below the exact threshold; put
                    // the entry back and retry at the next event time.
                    self.deadlines.push(Reverse(Deadline { at, id, kind }));
                    break;
                }
                None => {}
            }
        }
    }

    /// First output token observed. A late first token (TTFT already
    /// blown, by the same comparison [`RequestRecord::meets`] will apply)
    /// counts immediately; a timely one settles TTFT and arms the
    /// decode-phase TPOT deadline (single-token requests have no TPOT
    /// clock — their recorded TPOT is defined as 0).
    pub fn on_first_token(&mut self, id: u64, now: f64) {
        let (late, arm_tpot) = match self.tracked.get_mut(&id) {
            Some(t) => {
                if t.first_token.is_some() {
                    return;
                }
                if now - t.arrival > t.slo.ttft {
                    (Some(t.class), None)
                } else {
                    t.first_token = Some(now);
                    let budget = t.slo.tpot * t.output_len.saturating_sub(1) as f64;
                    let deadline = (t.output_len > 1).then(|| now + budget);
                    (None, deadline)
                }
            }
            None => return,
        };
        if let Some(class) = late {
            self.tracked.remove(&id);
            self.violate(class, now);
        } else if let Some(at) = arm_tpot {
            self.deadlines.push(Reverse(Deadline { at, id, kind: DeadlineKind::Tpot }));
        }
    }

    /// Completion observed: the finalized record either meets its class
    /// SLO pair or is a violation. Resolves the watch either way.
    pub fn on_complete(&mut self, rec: &RequestRecord, now: f64) {
        if let Some(t) = self.tracked.remove(&rec.id) {
            if !rec.meets(&t.slo) {
                self.violate(t.class, now);
            }
        }
    }

    /// Admission rejection: the request will never complete, so it is a
    /// guaranteed violation under strict attainment.
    pub fn on_reject(&mut self, id: u64, now: f64) {
        if let Some(t) = self.tracked.remove(&id) {
            self.violate(t.class, now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slo() -> SloSpec {
        SloSpec::new(1.0, 0.1)
    }

    fn rec(id: u64, arrival: f64, first: f64, done: f64, out: usize) -> RequestRecord {
        RequestRecord {
            id,
            arrival,
            first_token: first,
            completion: done,
            input_len: 64,
            output_len: out,
        }
    }

    #[test]
    fn deadline_pass_without_first_token_is_a_violation() {
        let mut m = SloMonitor::new(0.9, 1);
        for id in 0..10 {
            m.track(id, 0.0, slo(), 0, 5);
        }
        m.advance(0.5);
        assert_eq!(m.violations(), 0);
        m.advance(1.0); // exactly on the deadline: ttft == slo still meets
        assert_eq!(m.violations(), 0);
        assert!(!m.decided());
        m.advance(1.5); // one second of SLO, all ten blown
        assert_eq!(m.violations(), 10);
        assert!(m.decided());
        assert_eq!(m.decided_at(), Some(1.5));
    }

    #[test]
    fn decides_exactly_when_target_becomes_unreachable() {
        // 10 arrivals at P90: the budget is one violation; the second
        // guaranteed miss decides the verdict.
        let mut m = SloMonitor::new(0.9, 1);
        for id in 0..10 {
            m.track(id, id as f64, slo(), 0, 5);
        }
        m.advance(2.5); // id 0 (deadline 1.0) and id 1 (deadline 2.0) blown
        assert_eq!(m.violations(), 2);
        assert!(m.decided());
        // A P50 monitor with the same stream is still undecided.
        let mut loose = SloMonitor::new(0.5, 1);
        for id in 0..10 {
            loose.track(id, id as f64, slo(), 0, 5);
        }
        loose.advance(2.5);
        assert_eq!(loose.violations(), 2);
        assert!(!loose.decided());
    }

    #[test]
    fn timely_first_token_defuses_the_deadline() {
        let mut m = SloMonitor::new(0.9, 1);
        for id in 0..4 {
            // TPOT budget 5.0s (51 tokens at 0.1): no decode deadline
            // fires inside this test's horizon.
            m.track(id, 0.0, slo(), 0, 51);
        }
        m.on_first_token(0, 0.5);
        m.on_first_token(1, 1.0); // exactly at the deadline: meets
        m.advance(5.0);
        assert_eq!(m.violations(), 2); // only ids 2 and 3
        // A completion meeting both SLOs never counts.
        m.on_complete(&rec(0, 0.0, 0.5, 1.0, 6), 1.0);
        assert_eq!(m.violations(), 2);
    }

    #[test]
    fn late_first_token_and_blown_tpot_count_once_each() {
        let mut m = SloMonitor::new(0.6, 1);
        for id in 0..4 {
            m.track(id, 0.0, slo(), 0, 11);
        }
        m.on_first_token(0, 2.0); // ttft 2.0 > 1.0: immediate violation
        assert_eq!(m.violations(), 1);
        // Completing id 0 later must not double count.
        m.on_complete(&rec(0, 0.0, 2.0, 2.1, 2), 2.1);
        assert_eq!(m.violations(), 1);
        // id 1: timely first token, then TPOT blown at completion.
        m.on_first_token(1, 0.5);
        m.on_complete(&rec(1, 0.0, 0.5, 3.5, 11), 3.5); // tpot 0.3 > 0.1
        assert_eq!(m.violations(), 2);
        assert!(m.decided()); // best case 2/4 = 0.5 < 0.6 target
    }

    #[test]
    fn rejects_are_guaranteed_violations() {
        let mut m = SloMonitor::new(0.9, 1);
        for id in 0..3 {
            m.track(id, 0.0, slo(), 0, 5);
        }
        m.on_reject(0, 0.1);
        assert_eq!(m.violations(), 1);
        assert!(m.decided()); // 2/3 < 0.9
        m.on_reject(99, 0.1); // unknown ids are ignored
        assert_eq!(m.violations(), 1);
    }

    #[test]
    fn per_class_budgets_are_independent() {
        // Class 0 has 10 arrivals, class 1 has 2; one miss in class 1
        // (best 0.5) decides a P90 verdict even though class 0 is clean.
        let mut m = SloMonitor::new(0.9, 2);
        for id in 0..10 {
            // Single-token requests: no TPOT clock, so class 0 stays clean
            // no matter how far the clock advances.
            m.track(id, 0.0, slo(), 0, 1);
        }
        m.track(100, 0.0, slo(), 1, 1);
        m.track(101, 0.0, slo(), 1, 1);
        for id in 0..10 {
            m.on_first_token(id, 0.2);
        }
        m.on_first_token(100, 0.2);
        m.advance(10.0); // id 101 blows its TTFT deadline
        assert_eq!(m.violations(), 1);
        assert!(m.decided());
    }

    #[test]
    fn untracked_requests_are_invisible() {
        let mut m = SloMonitor::new(0.9, 1);
        m.track(1, 0.0, slo(), 0, 5);
        m.on_first_token(7, 99.0);
        m.on_complete(&rec(8, 0.0, 99.0, 99.0, 5), 99.0);
        assert_eq!(m.violations(), 0);
        assert_eq!(m.tracked_arrivals(), 1);
    }

    /// The decode-phase deadline: a request whose first token was timely
    /// but whose TPOT budget (`slo.tpot · (output_len - 1)`) runs out with
    /// no completion is a guaranteed violation — any future completion
    /// already averages over budget.
    #[test]
    fn tpot_deadline_fires_without_completion() {
        // Binary-exact timestamps so "exactly on budget" is exact: the
        // check divides like RequestRecord::tpot, and (1.5 - 0.25) / 10
        // == 0.125 == slo.tpot must still meet.
        let slo = SloSpec::new(1.0, 0.125);
        let mut m = SloMonitor::new(0.9, 1);
        for id in 0..10 {
            m.track(id, 0.0, slo, 0, 11); // budget: 1.25s after first token
        }
        for id in 0..10 {
            m.on_first_token(id, 0.25);
        }
        m.advance(1.5); // exactly on the budget: 0.125 per token still meets
        assert_eq!(m.violations(), 0);
        assert!(!m.decided());
        m.advance(2.0); // 0.175 per token > 0.125: all ten blown
        assert_eq!(m.violations(), 10);
        assert!(m.decided());
        assert_eq!(m.decided_at(), Some(2.0));
    }

    #[test]
    fn completion_defuses_the_tpot_deadline() {
        let mut m = SloMonitor::new(0.9, 1);
        m.track(0, 0.0, slo(), 0, 11);
        m.track(1, 0.0, slo(), 0, 11);
        m.on_first_token(0, 0.2);
        m.on_first_token(1, 0.2);
        // id 0 completes inside its budget with a meeting TPOT (0.05/token).
        m.on_complete(&rec(0, 0.0, 0.2, 0.7, 11), 0.7);
        assert_eq!(m.violations(), 0);
        m.advance(10.0); // only id 1's decode deadline is still live
        assert_eq!(m.violations(), 1);
        // The stale deadline of the completed request never re-fires.
        m.advance(20.0);
        assert_eq!(m.violations(), 1);
    }

    /// Single-token requests have no inter-token time (recorded TPOT is 0
    /// by definition), so a timely first token settles them for good.
    #[test]
    fn single_token_requests_never_arm_a_tpot_deadline() {
        let mut m = SloMonitor::new(0.9, 1);
        m.track(0, 0.0, slo(), 0, 1);
        m.on_first_token(0, 0.5);
        m.advance(1e6);
        assert_eq!(m.violations(), 0);
        assert!(!m.decided());
    }

    /// The TPOT deadline decides strictly earlier than the completion-time
    /// check would: violations are counted while the requests are still
    /// in flight, which is what lets overload probes abandon sooner.
    #[test]
    fn tpot_deadline_decides_before_any_completion() {
        let mut m = SloMonitor::new(0.9, 1);
        for id in 0..10 {
            m.track(id, 0.0, SloSpec::new(5.0, 0.1), 0, 101); // 10s budget
        }
        for id in 0..10 {
            m.on_first_token(id, 1.0);
        }
        m.advance(12.0); // 11s elapsed > 10s budget, nothing completed
        assert!(m.decided(), "verdict must not wait for completions");
        // The completion-time path agrees when the stragglers finish.
        m.on_complete(&rec(0, 0.0, 1.0, 30.0, 101), 30.0);
        assert_eq!(m.violations(), 10, "no double count on late completion");
    }
}
