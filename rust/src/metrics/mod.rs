//! Request-level latency records, SLO attainment, and goodput accounting —
//! the measurement side of the paper's evaluation (§3.3, §4.1).
//!
//! Metric definitions follow the paper's *stricter* convention (§3.3): the
//! reported TTFT includes queueing and the phase-switching wait, i.e.
//! `first_token_time - arrival`; TPOT is measured after the first token,
//! per request, as the mean inter-token time.

pub mod collector;
pub mod monitor;

pub use collector::Collector;
pub use monitor::{AbandonPolicy, SloMonitor};

use crate::util::percentile_sorted;

/// Completed-request latency record.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival: f64,
    /// Time the first output token was produced (end of prefill, after any
    /// queueing/phase-switch wait — the §3.3 strict TTFT reference point).
    pub first_token: f64,
    /// Time the last output token was produced.
    pub completion: f64,
    pub input_len: usize,
    pub output_len: usize,
}

impl RequestRecord {
    /// Strict TTFT: queueing + phase-switch wait + prefill execution.
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Mean time per output token after the first.
    pub fn tpot(&self) -> f64 {
        if self.output_len <= 1 {
            return 0.0;
        }
        (self.completion - self.first_token) / (self.output_len - 1) as f64
    }

    /// End-to-end latency.
    pub fn e2e(&self) -> f64 {
        self.completion - self.arrival
    }

    /// Does this request meet both SLOs?
    pub fn meets(&self, slo: &SloSpec) -> bool {
        self.ttft() <= slo.ttft && self.tpot() <= slo.tpot
    }
}

/// An SLO pair (paper Table 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    pub ttft: f64,
    pub tpot: f64,
}

impl SloSpec {
    pub fn new(ttft: f64, tpot: f64) -> Self {
        SloSpec { ttft, tpot }
    }
}

/// Attainment level: the paper evaluates P50 / P90 / P99 (fraction of
/// requests that must meet the SLO pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attainment {
    P50,
    P90,
    P99,
}

impl Attainment {
    pub fn fraction(&self) -> f64 {
        match self {
            Attainment::P50 => 0.50,
            Attainment::P90 => 0.90,
            Attainment::P99 => 0.99,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Attainment::P50 => "P50",
            Attainment::P90 => "P90",
            Attainment::P99 => "P99",
        }
    }

    pub fn all() -> [Attainment; 3] {
        [Attainment::P50, Attainment::P90, Attainment::P99]
    }

    /// Parse "p50" / "p90" / "p99" (case-insensitive) — the CLI spelling
    /// shared by the `goodput` and `frontier` subcommands.
    pub fn by_name(name: &str) -> Option<Attainment> {
        match name.to_ascii_lowercase().as_str() {
            "p50" => Some(Attainment::P50),
            "p90" => Some(Attainment::P90),
            "p99" => Some(Attainment::P99),
            _ => None,
        }
    }
}

/// Summary statistics over a set of completed requests.
#[derive(Debug, Clone)]
pub struct Summary {
    pub count: usize,
    pub ttft_p50: f64,
    pub ttft_p90: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p90: f64,
    pub tpot_p99: f64,
    pub attained_frac: f64,
    pub throughput_rps: f64,
    pub token_throughput: f64,
}

/// Fraction of records meeting the SLO pair.
pub fn attainment_fraction(records: &[RequestRecord], slo: &SloSpec) -> f64 {
    if records.is_empty() {
        return 0.0;
    }
    records.iter().filter(|r| r.meets(slo)).count() as f64 / records.len() as f64
}

/// Whether the records meet `level` attainment of the SLOs.
pub fn meets_attainment(records: &[RequestRecord], slo: &SloSpec, level: Attainment) -> bool {
    attainment_fraction(records, slo) >= level.fraction()
}

/// Build a [`Summary`] over `records` for the window `[0, duration]`.
pub fn summarize(records: &[RequestRecord], slo: &SloSpec, duration: f64) -> Summary {
    summarize_from(records.iter(), slo, duration)
}

/// [`summarize`] over any borrowed record stream (e.g. the collector's
/// clone-free [`Collector::window_records`] view). Latency vectors are
/// sorted once and every percentile reads the sorted copy
/// ([`crate::util::percentile_sorted`]) instead of re-sorting per call;
/// the numbers are bit-identical to the sort-per-percentile path.
pub fn summarize_from<'a, I>(records: I, slo: &SloSpec, duration: f64) -> Summary
where
    I: Iterator<Item = &'a RequestRecord>,
{
    let mut ttfts: Vec<f64> = Vec::new();
    let mut tpots: Vec<f64> = Vec::new();
    let mut met = 0usize;
    let mut tokens = 0usize;
    for r in records {
        ttfts.push(r.ttft());
        tpots.push(r.tpot());
        tokens += r.output_len;
        if r.meets(slo) {
            met += 1;
        }
    }
    let count = ttfts.len();
    // Match `util::percentile`'s contract exactly: NaN samples dropped,
    // then a total-order sort.
    ttfts.retain(|x| !x.is_nan());
    tpots.retain(|x| !x.is_nan());
    ttfts.sort_by(f64::total_cmp);
    tpots.sort_by(f64::total_cmp);
    Summary {
        count,
        ttft_p50: percentile_sorted(&ttfts, 50.0),
        ttft_p90: percentile_sorted(&ttfts, 90.0),
        ttft_p99: percentile_sorted(&ttfts, 99.0),
        tpot_p50: percentile_sorted(&tpots, 50.0),
        tpot_p90: percentile_sorted(&tpots, 90.0),
        tpot_p99: percentile_sorted(&tpots, 99.0),
        attained_frac: if count == 0 { 0.0 } else { met as f64 / count as f64 },
        throughput_rps: count as f64 / duration.max(1e-9),
        token_throughput: tokens as f64 / duration.max(1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, first: f64, done: f64, out: usize) -> RequestRecord {
        RequestRecord {
            id: 0,
            arrival,
            first_token: first,
            completion: done,
            input_len: 100,
            output_len: out,
        }
    }

    #[test]
    fn ttft_tpot_arithmetic() {
        let r = rec(10.0, 10.5, 12.5, 21);
        assert!((r.ttft() - 0.5).abs() < 1e-12);
        assert!((r.tpot() - 0.1).abs() < 1e-12);
        assert!((r.e2e() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn single_token_request_has_zero_tpot() {
        let r = rec(0.0, 1.0, 1.0, 1);
        assert_eq!(r.tpot(), 0.0);
        assert!(r.meets(&SloSpec::new(2.0, 0.01)));
    }

    #[test]
    fn attainment_levels() {
        let slo = SloSpec::new(1.0, 0.1);
        let mut records = Vec::new();
        for i in 0..100 {
            // 95 meet the SLO, 5 miss on TTFT.
            let ttft = if i < 95 { 0.5 } else { 3.0 };
            records.push(rec(0.0, ttft, ttft + 1.0, 11));
        }
        assert!((attainment_fraction(&records, &slo) - 0.95).abs() < 1e-9);
        assert!(meets_attainment(&records, &slo, Attainment::P50));
        assert!(meets_attainment(&records, &slo, Attainment::P90));
        assert!(!meets_attainment(&records, &slo, Attainment::P99));
    }

    #[test]
    fn tpot_violation_detected() {
        let slo = SloSpec::new(10.0, 0.1);
        let slow = rec(0.0, 1.0, 1.0 + 20.0 * 0.3, 21); // tpot = 0.3
        assert!(!slow.meets(&slo));
    }

    #[test]
    fn summary_sane() {
        let slo = SloSpec::new(1.0, 0.1);
        let records: Vec<_> = (0..10)
            .map(|i| rec(i as f64, i as f64 + 0.2, i as f64 + 1.0, 11))
            .collect();
        let s = summarize(&records, &slo, 10.0);
        assert_eq!(s.count, 10);
        assert!((s.throughput_rps - 1.0).abs() < 1e-9);
        assert!((s.attained_frac - 1.0).abs() < 1e-9);
        assert!((s.ttft_p50 - 0.2).abs() < 1e-6);
        assert!((s.token_throughput - 11.0).abs() < 1e-9);
    }

    /// The sort-once percentile path must be bit-identical to calling
    /// `util::percentile` (which re-sorts) on the raw unsorted vectors.
    #[test]
    fn summarize_matches_the_unsorted_percentile_path() {
        use crate::util::percentile;
        let slo = SloSpec::new(1.0, 0.1);
        // Deterministic scrambled latencies, single-token requests mixed in.
        let records: Vec<_> = (0..97u64)
            .map(|i| {
                let a = ((i * 37) % 97) as f64 * 0.11;
                let out = if i % 5 == 0 { 1 } else { 10 + (i % 7) as usize };
                rec(a, a + 0.1 + ((i * 13) % 17) as f64 * 0.07, a + 2.0, out)
            })
            .collect();
        let s = summarize(&records, &slo, 60.0);
        let ttfts: Vec<f64> = records.iter().map(|r| r.ttft()).collect();
        let tpots: Vec<f64> = records.iter().map(|r| r.tpot()).collect();
        for (got, want) in [
            (s.ttft_p50, percentile(&ttfts, 50.0)),
            (s.ttft_p90, percentile(&ttfts, 90.0)),
            (s.ttft_p99, percentile(&ttfts, 99.0)),
            (s.tpot_p50, percentile(&tpots, 50.0)),
            (s.tpot_p90, percentile(&tpots, 90.0)),
            (s.tpot_p99, percentile(&tpots, 99.0)),
        ] {
            assert_eq!(got.to_bits(), want.to_bits(), "{got} vs {want}");
        }
        assert!((s.attained_frac - attainment_fraction(&records, &slo)).abs() < 1e-15);
        // The iterator entry point agrees with the slice entry point.
        let s2 = summarize_from(records.iter(), &slo, 60.0);
        assert_eq!(s.ttft_p99.to_bits(), s2.ttft_p99.to_bits());
        assert_eq!(s.count, s2.count);
    }

    #[test]
    fn attainment_by_name() {
        assert_eq!(Attainment::by_name("p90"), Some(Attainment::P90));
        assert_eq!(Attainment::by_name("P99"), Some(Attainment::P99));
        assert_eq!(Attainment::by_name("p50"), Some(Attainment::P50));
        assert_eq!(Attainment::by_name("p75"), None);
        for level in Attainment::all() {
            assert_eq!(Attainment::by_name(level.label()), Some(level));
        }
    }

    #[test]
    fn empty_records() {
        let slo = SloSpec::new(1.0, 0.1);
        assert_eq!(attainment_fraction(&[], &slo), 0.0);
        let s = summarize(&[], &slo, 1.0);
        assert_eq!(s.count, 0);
    }
}
