//! Streaming metrics collector: accumulates per-request token timestamps
//! during a run (simulated or live) and finalizes [`RequestRecord`]s.
//!
//! Also maintains windowed attainment series for the Figure 10 experiment
//! (SLO attainment sampled every 30 s while the request rate ramps), and
//! optionally hosts a [`SloMonitor`]: when armed, the collector forwards
//! every token event to it and latches a *scoring snapshot* — the length
//! of the completed-record log — the instant the monitor proves the
//! attainment target unreachable. Scoring through that snapshot is what
//! makes an early-abandoned run and a full run report identical numbers.

use std::collections::HashMap;

use super::monitor::SloMonitor;
use super::{RequestRecord, SloSpec};
use crate::sim::faults::FaultEvent;
use crate::trace::{RejectCause, TraceEvent, TraceKind, TraceSink, NO_INSTANCE, NO_REQ};
use crate::workload::{Request, RETRY_ID_BASE};

/// In-flight bookkeeping, struct-of-arrays: one *slot* per open request,
/// its fields split across parallel columns, with freed slots recycled
/// through a free list. Two properties matter on the engine hot path:
/// * columns and the id index retain capacity across [`clear`], so a
///   recycled collector's per-request bookkeeping allocates nothing once
///   the columns have grown to a run's steady-state open-request count;
/// * slot values stay readable after [`remove`] detaches the id (until
///   the slot is reused), which lets completion read its columns without
///   copying the whole row out first.
///
/// [`clear`]: OpenTable::clear
/// [`remove`]: OpenTable::remove
#[derive(Debug, Default)]
struct OpenTable {
    /// Request id → slot.
    index: HashMap<u64, u32>,
    /// Slots freed by [`OpenTable::remove`], ready for reuse.
    free: Vec<u32>,
    arrival: Vec<f64>,
    input_len: Vec<usize>,
    first_token: Vec<f64>,
    /// Whether `first_token[slot]` has been recorded (split from the
    /// value column: an `Option<f64>` per slot would defeat the flat
    /// f64 column layout).
    has_first: Vec<bool>,
    last_token: Vec<f64>,
    tokens: Vec<usize>,
}

impl OpenTable {
    /// Open a slot for `id` (no-op if `id` is already open).
    fn insert(&mut self, id: u64, arrival: f64, input_len: usize) {
        use std::collections::hash_map::Entry;
        let slot = match self.index.entry(id) {
            Entry::Occupied(_) => return,
            Entry::Vacant(v) => {
                let slot = match self.free.pop() {
                    Some(s) => s,
                    None => {
                        let s = self.arrival.len() as u32;
                        self.arrival.push(0.0);
                        self.input_len.push(0);
                        self.first_token.push(0.0);
                        self.has_first.push(false);
                        self.last_token.push(0.0);
                        self.tokens.push(0);
                        s
                    }
                };
                *v.insert(slot)
            }
        };
        let i = slot as usize;
        self.arrival[i] = arrival;
        self.input_len[i] = input_len;
        self.first_token[i] = 0.0;
        self.has_first[i] = false;
        self.last_token[i] = arrival;
        self.tokens[i] = 0;
    }

    /// The slot currently holding `id`, if open.
    fn slot(&self, id: u64) -> Option<usize> {
        self.index.get(&id).map(|&s| s as usize)
    }

    /// Close `id`'s slot and queue it for reuse. The returned slot's
    /// columns remain readable until the next [`OpenTable::insert`].
    fn remove(&mut self, id: u64) -> Option<usize> {
        let slot = self.index.remove(&id)?;
        self.free.push(slot);
        Some(slot as usize)
    }

    fn len(&self) -> usize {
        self.index.len()
    }

    /// Drop all state, keeping every column's capacity.
    fn clear(&mut self) {
        self.index.clear();
        self.free.clear();
        self.arrival.clear();
        self.input_len.clear();
        self.first_token.clear();
        self.has_first.clear();
        self.last_token.clear();
        self.tokens.clear();
    }
}

thread_local! {
    /// One spare collector per thread, mirroring the engine's scheduler
    /// pool: probe searches build a collector per run, and reusing the
    /// previous run's grown columns/log is what keeps warm runs
    /// allocation-free. `Cell`, not `RefCell`: take/put can't panic.
    static SPARE: std::cell::Cell<Option<Collector>> =
        const { std::cell::Cell::new(None) };
}

/// Collects token events and produces completed [`RequestRecord`]s.
#[derive(Debug, Default)]
pub struct Collector {
    open: OpenTable,
    done: Vec<RequestRecord>,
    /// Count of requests rejected at admission (capacity overflow).
    pub rejected: usize,
    monitor: Option<SloMonitor>,
    /// `done.len()` at the moment the monitor decided the verdict.
    decision_cut: Option<usize>,
    /// Latest simulation time observed through [`Collector::observe_time`]
    /// (the engine advances it once per event).
    clock: f64,
    /// When set (client-in-the-loop runs), rejected ids are queued in
    /// [`Collector::pending_rejects`] for the engine to hand to the
    /// client loop as fast error feedback. Off by default so open-loop
    /// runs stay bit-identical.
    track_rejects: bool,
    pending_rejects: Vec<u64>,
    /// Flight-recorder sink ([`crate::trace`]). `None` (the default)
    /// keeps every trace hook an inlined no-op: recorder-off runs are
    /// bit-identical to pre-recorder builds and stay allocation-free on
    /// the warm path.
    sink: Option<TraceSink>,
}

impl Collector {
    pub fn new() -> Self {
        Self::default()
    }

    /// A collector with an armed SLO monitor: the verdict is watched
    /// online and the scoring snapshot latched at decision time.
    pub fn with_monitor(monitor: SloMonitor) -> Self {
        Collector { monitor: Some(monitor), ..Default::default() }
    }

    /// Reset for reuse, retaining every buffer's capacity (the id index,
    /// the slot columns, and the completed-record log). Observable state
    /// is indistinguishable from a fresh [`Collector::new`] /
    /// [`Collector::with_monitor`] — only capacity survives, which is
    /// what makes every run after the first allocation-free in the
    /// engine's hot loop (see [`crate::sim::RunStats::allocs`]).
    pub fn recycle(&mut self, monitor: Option<SloMonitor>) {
        self.open.clear();
        self.done.clear();
        self.rejected = 0;
        self.monitor = monitor;
        self.decision_cut = None;
        self.clock = 0.0;
        self.track_rejects = false;
        self.pending_rejects.clear();
        self.sink = None;
    }

    /// Attach a flight-recorder sink: lifecycle hooks start appending
    /// typed [`TraceEvent`]s. Attaching changes no simulation decision.
    pub fn attach_sink(&mut self, sink: TraceSink) {
        self.sink = Some(sink);
    }

    /// Detach and return the sink (the harvest point after a run).
    pub fn take_sink(&mut self) -> Option<TraceSink> {
        self.sink.take()
    }

    /// Append one event when a sink is attached; no-op otherwise.
    #[inline]
    pub fn trace(&mut self, ev: TraceEvent) {
        if let Some(s) = self.sink.as_mut() {
            s.push(ev);
        }
    }

    /// Record an instance phase window `[t0, t1]`, coalescing with the
    /// instance's previous same-kind window; no-op without a sink.
    #[inline]
    pub fn trace_phase(&mut self, kind: TraceKind, instance: u32, t0: f64, t1: f64) {
        if let Some(s) = self.sink.as_mut() {
            s.push_phase(kind, instance, t0, t1);
        }
    }

    /// Record an injected fault as lifecycle instants; no-op without a
    /// sink. Both engine variants call this just before delivering the
    /// fault to the system.
    pub fn trace_fault(&mut self, fault: &FaultEvent, now: f64) {
        if self.sink.is_none() {
            return;
        }
        let ev = match *fault {
            FaultEvent::InstanceDown { instance } => {
                TraceEvent::instant(TraceKind::Down, NO_REQ, instance as u32, now)
            }
            FaultEvent::InstanceUp { instance } => {
                TraceEvent::instant(TraceKind::Up, NO_REQ, instance as u32, now)
            }
            FaultEvent::PreemptNotice { instance } => {
                TraceEvent::instant(TraceKind::PreemptNotice, NO_REQ, instance as u32, now)
            }
            FaultEvent::LinkDegrade { .. } => {
                TraceEvent::instant(TraceKind::LinkDegrade, NO_REQ, NO_INSTANCE, now)
            }
            FaultEvent::LinkRestore => {
                TraceEvent::instant(TraceKind::LinkRestore, NO_REQ, NO_INSTANCE, now)
            }
        };
        self.trace(ev);
    }

    /// A recycled collector from this thread's spare slot (fresh if the
    /// slot is empty): behaviorally identical to
    /// `monitor.map_or_else(Collector::new, Collector::with_monitor)`,
    /// but capacity-warm. Pair with [`Collector::release`] when the
    /// probe is scored so the next run on this thread reuses it.
    pub fn pooled(monitor: Option<SloMonitor>) -> Collector {
        let mut c = SPARE.with(std::cell::Cell::take).unwrap_or_default();
        c.recycle(monitor);
        c
    }

    /// Park this collector in the thread's spare slot for reuse by the
    /// next [`Collector::pooled`] call.
    pub fn release(self) {
        SPARE.with(|s| s.set(Some(self)));
    }

    fn latch_decision(&mut self) {
        if self.decision_cut.is_none() && self.monitor.as_ref().is_some_and(|m| m.decided()) {
            self.decision_cut = Some(self.done.len());
        }
    }

    /// Advance the monitor clock (TTFT deadline sweep). The engine calls
    /// this once per event; without a monitor it is a no-op.
    pub fn observe_time(&mut self, now: f64) {
        self.clock = self.clock.max(now);
        if let Some(m) = self.monitor.as_mut() {
            m.advance(now);
        }
        self.latch_decision();
    }

    /// Has the armed monitor proven the attainment target unreachable?
    pub fn decided(&self) -> bool {
        self.decision_cut.is_some()
    }

    /// The armed monitor, if any (violation counts, decision time).
    pub fn monitor(&self) -> Option<&SloMonitor> {
        self.monitor.as_ref()
    }

    /// Register arrival (idempotent per id).
    pub fn on_arrival(&mut self, req: &Request) {
        self.open.insert(req.id, req.arrival, req.input_len);
        if self.sink.is_some() {
            let kind =
                if req.id >= RETRY_ID_BASE { TraceKind::Retry } else { TraceKind::Arrive };
            self.trace(TraceEvent::instant(kind, req.id, NO_INSTANCE, req.arrival));
        }
    }

    /// Record the first output token (end of prefill).
    pub fn on_first_token(&mut self, id: u64, now: f64) {
        if let Some(i) = self.open.slot(id) {
            debug_assert!(!self.open.has_first[i], "duplicate first token for {id}");
            self.open.first_token[i] = now;
            self.open.has_first[i] = true;
            self.open.last_token[i] = now;
            self.open.tokens[i] = 1;
            self.trace(TraceEvent::instant(TraceKind::FirstToken, id, NO_INSTANCE, now));
        }
        if let Some(m) = self.monitor.as_mut() {
            m.on_first_token(id, now);
        }
        self.latch_decision();
    }

    /// Record a subsequent decode token.
    pub fn on_token(&mut self, id: u64, now: f64) {
        if let Some(i) = self.open.slot(id) {
            self.open.last_token[i] = now;
            self.open.tokens[i] += 1;
        }
    }

    /// Finish a request; moves it to the completed set.
    pub fn on_complete(&mut self, id: u64, now: f64) {
        if let Some(i) = self.open.remove(id) {
            // The freed slot's columns stay valid until its next reuse.
            let first =
                if self.open.has_first[i] { self.open.first_token[i] } else { now };
            let rec = RequestRecord {
                id,
                arrival: self.open.arrival[i],
                first_token: first,
                completion: now.max(first),
                input_len: self.open.input_len[i],
                output_len: self.open.tokens[i].max(1),
            };
            if let Some(m) = self.monitor.as_mut() {
                m.on_complete(&rec, now);
            }
            self.done.push(rec);
            self.latch_decision();
            self.trace(TraceEvent::instant(TraceKind::Complete, id, NO_INSTANCE, now));
        }
    }

    /// Request rejected at admission — tracked separately so overloaded
    /// systems can't improve their attainment by shedding load invisibly.
    pub fn on_reject(&mut self, id: u64) {
        self.on_reject_as(id, RejectCause::Other);
    }

    /// [`Collector::on_reject`] with a tagged cause: shed sites name the
    /// *reason* (queue full, deadline, priority, hopeless) so the trace
    /// miss-attribution histogram is causal. Identical bookkeeping.
    pub fn on_reject_as(&mut self, id: u64, cause: RejectCause) {
        if let Some(i) = self.open.remove(id) {
            // Rejections happen while dispatching an event, so the engine
            // clock (never behind the arrival) is the rejection time.
            let now = self.clock.max(self.open.arrival[i]);
            if let Some(m) = self.monitor.as_mut() {
                m.on_reject(id, now);
            }
            self.latch_decision();
            if self.track_rejects {
                self.pending_rejects.push(id);
            }
            self.trace(TraceEvent::instant(TraceKind::Reject(cause), id, NO_INSTANCE, now));
        }
        self.rejected += 1;
    }

    /// Arm client feedback: rejected ids queue up for
    /// [`Collector::pop_client_reject`]. Called by the engine's
    /// client-in-the-loop entry points only, so open-loop runs never pay
    /// for (or observe) the queue.
    pub fn enable_reject_tracking(&mut self) {
        self.track_rejects = true;
    }

    /// Drain one queued rejection (FIFO) for client retry scheduling.
    pub fn pop_client_reject(&mut self) -> Option<u64> {
        if self.pending_rejects.is_empty() {
            None
        } else {
            Some(self.pending_rejects.remove(0))
        }
    }

    /// Is `id` still open and waiting for its first token? `Some(true)`
    /// means the prefill hasn't been served yet (a client timeout firing
    /// now is a real timeout), `Some(false)` means the first token
    /// arrived while the request is still decoding, `None` means the
    /// request is no longer open (completed or rejected).
    pub fn first_token_pending(&self, id: u64) -> Option<bool> {
        self.open.slot(id).map(|i| !self.open.has_first[i])
    }

    pub fn completed(&self) -> &[RequestRecord] {
        &self.done
    }

    pub fn in_flight(&self) -> usize {
        self.open.len()
    }

    pub fn into_records(self) -> Vec<RequestRecord> {
        self.done
    }

    /// How much of the completed log is eligible for probe scoring:
    /// everything, unless the monitor decided mid-run — then only the
    /// records completed before the decision, so early-abandoned and
    /// full runs score bit-identically.
    pub fn scoring_cut(&self) -> usize {
        self.decision_cut.unwrap_or(self.done.len())
    }

    /// Borrow-based windowed view over the scoring records (arrival in
    /// `[t0, t1)`): the clone-free replacement for [`records_in_window`]
    /// on the probe scoring path.
    ///
    /// [`records_in_window`]: Collector::records_in_window
    pub fn window_records(&self, t0: f64, t1: f64) -> impl Iterator<Item = &RequestRecord> + '_ {
        self.done[..self.scoring_cut()]
            .iter()
            .filter(move |r| r.arrival >= t0 && r.arrival < t1)
    }

    /// Completed records whose arrival fell in [t0, t1), over the *full*
    /// (uncut) log — used both to trim warm-up/cool-down and for Figure
    /// 10's 30-second attainment windows, including the live mitosis
    /// controller's mid-run view, which must never freeze at the
    /// monitor's decision snapshot. Probe *scoring* paths should prefer
    /// [`Collector::window_records`], which is clone-free and respects
    /// the snapshot.
    pub fn records_in_window(&self, t0: f64, t1: f64) -> Vec<RequestRecord> {
        self.done
            .iter()
            .filter(|r| r.arrival >= t0 && r.arrival < t1)
            .cloned()
            .collect()
    }

    /// Windowed attainment series over [0, horizon): one point per
    /// `window` seconds (Figure 10's y-axis).
    pub fn attainment_series(&self, slo: &SloSpec, window: f64, horizon: f64) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        // A non-positive (or NaN) window can never advance `t`: empty
        // series instead of an infinite loop.
        if !(window > 0.0) {
            return out;
        }
        let mut t = 0.0;
        while t < horizon {
            let recs = self.records_in_window(t, t + window);
            let frac = super::attainment_fraction(&recs, slo);
            out.push((t + window, if recs.is_empty() { 1.0 } else { frac }));
            t += window;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, arrival, input_len: 10, output_len: 5 }
    }

    #[test]
    fn lifecycle_produces_record() {
        let mut c = Collector::new();
        c.on_arrival(&req(1, 0.0));
        c.on_first_token(1, 0.4);
        for i in 1..5 {
            c.on_token(1, 0.4 + i as f64 * 0.05);
        }
        c.on_complete(1, 0.6);
        assert_eq!(c.in_flight(), 0);
        let r = &c.completed()[0];
        assert_eq!(r.output_len, 5);
        assert!((r.ttft() - 0.4).abs() < 1e-12);
        assert!((r.tpot() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn reject_is_counted_not_recorded() {
        let mut c = Collector::new();
        c.on_arrival(&req(1, 0.0));
        c.on_reject(1);
        assert_eq!(c.rejected, 1);
        assert!(c.completed().is_empty());
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn windowing() {
        let mut c = Collector::new();
        for (id, t) in [(1u64, 1.0), (2, 31.0), (3, 61.0)] {
            c.on_arrival(&req(id, t));
            c.on_first_token(id, t + 0.1);
            c.on_complete(id, t + 0.5);
        }
        assert_eq!(c.records_in_window(0.0, 30.0).len(), 1);
        assert_eq!(c.records_in_window(30.0, 60.0).len(), 1);
        assert_eq!(c.window_records(0.0, 30.0).count(), 1);
        assert_eq!(c.window_records(30.0, 60.0).count(), 1);
        assert_eq!(c.window_records(0.0, 90.0).count(), 3);
        let series = c.attainment_series(&SloSpec::new(1.0, 1.0), 30.0, 90.0);
        assert_eq!(series.len(), 3);
        assert!(series.iter().all(|(_, f)| *f == 1.0));
    }

    #[test]
    fn unknown_ids_ignored() {
        let mut c = Collector::new();
        c.on_first_token(99, 1.0);
        c.on_token(99, 1.1);
        c.on_complete(99, 1.2);
        assert!(c.completed().is_empty());
    }

    #[test]
    fn without_monitor_never_decides() {
        let mut c = Collector::new();
        c.observe_time(1e9);
        assert!(!c.decided());
        assert_eq!(c.scoring_cut(), 0);
        assert!(c.monitor().is_none());
    }

    #[test]
    fn armed_monitor_latches_the_scoring_snapshot() {
        // Two arrivals at P90: the budget is zero violations, so the
        // first blown deadline decides the verdict. A completion landing
        // after the decision must stay outside the scoring cut.
        let mut m = SloMonitor::new(0.9, 1);
        m.track(1, 0.0, SloSpec::new(1.0, 0.1), 0, 5);
        m.track(2, 0.0, SloSpec::new(1.0, 0.1), 0, 5);
        let mut c = Collector::with_monitor(m);
        c.on_arrival(&req(1, 0.0));
        c.on_arrival(&req(2, 0.0));
        c.observe_time(0.9);
        assert!(!c.decided());
        c.observe_time(2.0); // both TTFT deadlines blown: decided
        assert!(c.decided());
        assert_eq!(c.scoring_cut(), 0);
        c.on_first_token(1, 2.5);
        c.on_complete(1, 2.6);
        assert_eq!(c.completed().len(), 1);
        assert_eq!(c.scoring_cut(), 0, "post-decision completions excluded");
        assert_eq!(c.window_records(0.0, 10.0).count(), 0);
        assert_eq!(c.monitor().unwrap().violations(), 2);
    }

    #[test]
    fn double_arrival_is_idempotent() {
        let mut c = Collector::new();
        c.on_arrival(&req(1, 0.0));
        c.on_first_token(1, 0.2);
        // A duplicate arrival (different payload) must not reset the slot.
        c.on_arrival(&Request { id: 1, arrival: 5.0, input_len: 99, output_len: 1 });
        c.on_complete(1, 0.5);
        let r = &c.completed()[0];
        assert_eq!(r.arrival, 0.0);
        assert_eq!(r.input_len, 10);
        assert!((r.first_token - 0.2).abs() < 1e-12);
    }

    #[test]
    fn slot_reuse_does_not_leak_state_between_requests() {
        let mut c = Collector::new();
        c.on_arrival(&req(1, 0.0));
        c.on_first_token(1, 0.4);
        for i in 1..4 {
            c.on_token(1, 0.4 + i as f64 * 0.05);
        }
        c.on_complete(1, 0.6);
        // id 2 reuses id 1's freed slot: it must start with no first
        // token and zero decode tokens, not id 1's leftovers.
        c.on_arrival(&req(2, 1.0));
        c.on_complete(2, 1.5); // completed without ever emitting a token
        let r2 = &c.completed()[1];
        assert_eq!(r2.id, 2);
        assert_eq!(r2.arrival, 1.0);
        assert_eq!(r2.first_token, 1.5, "first_token must fall back to `now`");
        assert_eq!(r2.output_len, 1, "tokens.max(1), not the old slot's count");
    }

    #[test]
    fn recycle_resets_state_and_reruns_identically() {
        let run = |c: &mut Collector| {
            c.on_arrival(&req(1, 0.0));
            c.on_first_token(1, 0.4);
            c.on_token(1, 0.45);
            c.on_complete(1, 0.6);
            c.on_arrival(&req(2, 0.1));
            c.on_reject(2);
            c.completed().to_vec()
        };
        let mut c = Collector::new();
        let first = run(&mut c);
        assert_eq!(c.rejected, 1);
        c.recycle(None);
        assert_eq!(c.in_flight(), 0);
        assert_eq!(c.rejected, 0);
        assert!(c.completed().is_empty());
        assert!(!c.decided());
        let second = run(&mut c);
        assert_eq!(first, second, "a recycled collector must replay identically");
    }

    #[test]
    fn pooled_collector_round_trips_through_the_spare_slot() {
        let mut c = Collector::pooled(None);
        c.on_arrival(&req(1, 0.0));
        c.on_first_token(1, 0.1);
        c.on_complete(1, 0.2);
        assert_eq!(c.completed().len(), 1);
        c.release();
        // The next pooled() on this thread reuses it, fully reset.
        let c2 = Collector::pooled(None);
        assert!(c2.completed().is_empty());
        assert_eq!(c2.in_flight(), 0);
        assert_eq!(c2.rejected, 0);
        c2.release();
        // Arming a monitor through pooled() behaves like with_monitor.
        let mut m = SloMonitor::new(0.9, 1);
        m.track(1, 0.0, SloSpec::new(1.0, 0.1), 0, 5);
        let mut c3 = Collector::pooled(Some(m));
        c3.on_arrival(&req(1, 0.0));
        c3.observe_time(5.0); // TTFT deadline blown → verdict decided
        assert!(c3.decided());
    }

    #[test]
    fn window_records_edges_are_half_open() {
        // Arrivals exactly on window edges: [t0, t1) — t0 in, t1 out.
        let mut c = Collector::new();
        for (id, t) in [(1u64, 30.0), (2, 59.999999), (3, 60.0)] {
            c.on_arrival(&req(id, t));
            c.on_first_token(id, t + 0.1);
            c.on_complete(id, t + 0.5);
        }
        let in_window: Vec<u64> =
            c.window_records(30.0, 60.0).map(|r| r.id).collect();
        assert_eq!(in_window, vec![1, 2], "t0 inclusive, t1 exclusive");
        assert_eq!(c.window_records(60.0, 90.0).count(), 1);
        // Empty window (t0 == t1) selects nothing, even with an arrival
        // exactly at the boundary.
        assert_eq!(c.window_records(30.0, 30.0).count(), 0);
        assert_eq!(c.records_in_window(30.0, 30.0).len(), 0);
        // Inverted window selects nothing rather than panicking.
        assert_eq!(c.window_records(60.0, 30.0).count(), 0);
    }

    #[test]
    fn window_straddling_the_warmup_boundary_splits_cleanly() {
        // Warmup trim at t=30: a record at 29.9 scores in [0,30) only, a
        // record at 30.0 in [30,60) only — no double counting, no loss.
        let mut c = Collector::new();
        for (id, t) in [(1u64, 29.9), (2, 30.0)] {
            c.on_arrival(&req(id, t));
            c.on_first_token(id, t + 0.1);
            c.on_complete(id, t + 0.5);
        }
        let warm = c.window_records(0.0, 30.0).count();
        let scored = c.window_records(30.0, 60.0).count();
        assert_eq!((warm, scored), (1, 1));
        assert_eq!(warm + scored, c.completed().len());
    }

    #[test]
    fn attainment_series_degenerate_inputs_terminate() {
        let mut c = Collector::new();
        c.on_arrival(&req(1, 1.0));
        c.on_first_token(1, 1.1);
        c.on_complete(1, 1.5);
        let slo = SloSpec::new(1.0, 1.0);
        // Zero / negative / NaN windows: empty series, not a hang.
        assert!(c.attainment_series(&slo, 0.0, 90.0).is_empty());
        assert!(c.attainment_series(&slo, -5.0, 90.0).is_empty());
        assert!(c.attainment_series(&slo, f64::NAN, 90.0).is_empty());
        // Zero horizon: no window ever starts.
        assert!(c.attainment_series(&slo, 30.0, 0.0).is_empty());
        // A horizon shorter than one window still yields that window.
        let series = c.attainment_series(&slo, 30.0, 10.0);
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].0, 30.0);
    }

    #[test]
    fn sink_records_lifecycle_and_detaches() {
        use crate::trace::{RejectCause, TraceKind};
        let mut c = Collector::new();
        c.attach_sink(TraceSink::new());
        c.on_arrival(&req(1, 0.0));
        c.on_first_token(1, 0.4);
        c.on_token(1, 0.45);
        c.on_complete(1, 0.6);
        c.on_arrival(&req(2, 0.1));
        c.on_reject_as(2, RejectCause::QueueFull);
        c.on_arrival(&Request {
            id: RETRY_ID_BASE + 2,
            arrival: 0.2,
            input_len: 10,
            output_len: 5,
        });
        let sink = c.take_sink().expect("sink attached");
        let kinds: Vec<TraceKind> = sink.events().iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                TraceKind::Arrive,
                TraceKind::FirstToken,
                TraceKind::Complete,
                TraceKind::Arrive,
                TraceKind::Reject(RejectCause::QueueFull),
                TraceKind::Retry,
            ]
        );
        assert!(c.take_sink().is_none(), "take_sink detaches");
    }

    #[test]
    fn sink_does_not_change_records_and_recycle_drops_it() {
        let run = |c: &mut Collector| {
            c.on_arrival(&req(1, 0.0));
            c.on_first_token(1, 0.4);
            c.on_complete(1, 0.6);
            c.on_arrival(&req(2, 0.1));
            c.on_reject(2);
            c.completed().to_vec()
        };
        let mut plain = Collector::new();
        let without = run(&mut plain);
        let mut traced = Collector::new();
        traced.attach_sink(TraceSink::new());
        let with = run(&mut traced);
        assert_eq!(without, with, "recording must not change the records");
        assert_eq!(traced.rejected, plain.rejected);
        traced.recycle(None);
        assert!(traced.take_sink().is_none(), "recycle drops the sink");
    }

    #[test]
    fn healthy_run_with_monitor_scores_everything() {
        let mut m = SloMonitor::new(0.9, 1);
        m.track(1, 0.0, SloSpec::new(1.0, 1.0), 0, 5);
        let mut c = Collector::with_monitor(m);
        c.on_arrival(&req(1, 0.0));
        c.observe_time(0.2);
        c.on_first_token(1, 0.4);
        c.on_complete(1, 0.6);
        c.observe_time(50.0);
        assert!(!c.decided());
        assert_eq!(c.scoring_cut(), 1);
        assert_eq!(c.window_records(0.0, 10.0).count(), 1);
    }
}
