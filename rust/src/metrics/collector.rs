//! Streaming metrics collector: accumulates per-request token timestamps
//! during a run (simulated or live) and finalizes [`RequestRecord`]s.
//!
//! Also maintains windowed attainment series for the Figure 10 experiment
//! (SLO attainment sampled every 30 s while the request rate ramps).

use std::collections::HashMap;

use super::{RequestRecord, SloSpec};
use crate::workload::Request;

/// In-flight bookkeeping for one request.
#[derive(Debug, Clone)]
struct Open {
    arrival: f64,
    input_len: usize,
    first_token: Option<f64>,
    last_token: f64,
    tokens: usize,
}

/// Collects token events and produces completed [`RequestRecord`]s.
#[derive(Debug, Default)]
pub struct Collector {
    open: HashMap<u64, Open>,
    done: Vec<RequestRecord>,
    /// Count of requests rejected at admission (capacity overflow).
    pub rejected: usize,
}

impl Collector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register arrival (idempotent per id).
    pub fn on_arrival(&mut self, req: &Request) {
        self.open.entry(req.id).or_insert(Open {
            arrival: req.arrival,
            input_len: req.input_len,
            first_token: None,
            last_token: req.arrival,
            tokens: 0,
        });
    }

    /// Record the first output token (end of prefill).
    pub fn on_first_token(&mut self, id: u64, now: f64) {
        if let Some(o) = self.open.get_mut(&id) {
            debug_assert!(o.first_token.is_none(), "duplicate first token for {id}");
            o.first_token = Some(now);
            o.last_token = now;
            o.tokens = 1;
        }
    }

    /// Record a subsequent decode token.
    pub fn on_token(&mut self, id: u64, now: f64) {
        if let Some(o) = self.open.get_mut(&id) {
            o.last_token = now;
            o.tokens += 1;
        }
    }

    /// Finish a request; moves it to the completed set.
    pub fn on_complete(&mut self, id: u64, now: f64) {
        if let Some(o) = self.open.remove(&id) {
            let first = o.first_token.unwrap_or(now);
            self.done.push(RequestRecord {
                id,
                arrival: o.arrival,
                first_token: first,
                completion: now.max(first),
                input_len: o.input_len,
                output_len: o.tokens.max(1),
            });
        }
    }

    /// Request rejected at admission — tracked separately so overloaded
    /// systems can't improve their attainment by shedding load invisibly.
    pub fn on_reject(&mut self, id: u64) {
        self.open.remove(&id);
        self.rejected += 1;
    }

    pub fn completed(&self) -> &[RequestRecord] {
        &self.done
    }

    pub fn in_flight(&self) -> usize {
        self.open.len()
    }

    pub fn into_records(self) -> Vec<RequestRecord> {
        self.done
    }

    /// Completed records whose arrival fell in [t0, t1) — used both to trim
    /// warm-up/cool-down and for Figure 10's 30-second attainment windows.
    pub fn records_in_window(&self, t0: f64, t1: f64) -> Vec<RequestRecord> {
        self.done
            .iter()
            .filter(|r| r.arrival >= t0 && r.arrival < t1)
            .cloned()
            .collect()
    }

    /// Windowed attainment series over [0, horizon): one point per
    /// `window` seconds (Figure 10's y-axis).
    pub fn attainment_series(&self, slo: &SloSpec, window: f64, horizon: f64) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        let mut t = 0.0;
        while t < horizon {
            let recs = self.records_in_window(t, t + window);
            let frac = super::attainment_fraction(&recs, slo);
            out.push((t + window, if recs.is_empty() { 1.0 } else { frac }));
            t += window;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, arrival, input_len: 10, output_len: 5 }
    }

    #[test]
    fn lifecycle_produces_record() {
        let mut c = Collector::new();
        c.on_arrival(&req(1, 0.0));
        c.on_first_token(1, 0.4);
        for i in 1..5 {
            c.on_token(1, 0.4 + i as f64 * 0.05);
        }
        c.on_complete(1, 0.6);
        assert_eq!(c.in_flight(), 0);
        let r = &c.completed()[0];
        assert_eq!(r.output_len, 5);
        assert!((r.ttft() - 0.4).abs() < 1e-12);
        assert!((r.tpot() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn reject_is_counted_not_recorded() {
        let mut c = Collector::new();
        c.on_arrival(&req(1, 0.0));
        c.on_reject(1);
        assert_eq!(c.rejected, 1);
        assert!(c.completed().is_empty());
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn windowing() {
        let mut c = Collector::new();
        for (id, t) in [(1u64, 1.0), (2, 31.0), (3, 61.0)] {
            c.on_arrival(&req(id, t));
            c.on_first_token(id, t + 0.1);
            c.on_complete(id, t + 0.5);
        }
        assert_eq!(c.records_in_window(0.0, 30.0).len(), 1);
        assert_eq!(c.records_in_window(30.0, 60.0).len(), 1);
        let series = c.attainment_series(&SloSpec::new(1.0, 1.0), 30.0, 90.0);
        assert_eq!(series.len(), 3);
        assert!(series.iter().all(|(_, f)| *f == 1.0));
    }

    #[test]
    fn unknown_ids_ignored() {
        let mut c = Collector::new();
        c.on_first_token(99, 1.0);
        c.on_token(99, 1.1);
        c.on_complete(99, 1.2);
        assert!(c.completed().is_empty());
    }
}
